//! LU factorization with partial pivoting.
//!
//! Used for the general (possibly non-symmetric) solves: the `N²×N²` Woodbury
//! core `C⁻¹ + UᵀB⁻¹U` is symmetric only up to the shuffle permutation, and
//! the flipped inference of Sec. 4.1.2 can produce mildly non-symmetric
//! systems after round-off, so a pivoted LU is the robust default there.

use super::{Mat, EPS};

/// `P A = L U` with partial (row) pivoting.
#[derive(Clone, Debug)]
pub struct Lu {
    lu: Mat,
    piv: Vec<usize>,
    num_swaps: usize,
}

/// Error raised when the matrix is numerically singular.
#[derive(Debug, Clone, PartialEq)]
pub struct Singular {
    pub column: usize,
}

impl std::fmt::Display for Singular {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix numerically singular at column {}", self.column)
    }
}

impl std::error::Error for Singular {}

impl Lu {
    /// Factor a square matrix.
    pub fn factor(a: &Mat) -> Result<Self, Singular> {
        assert!(a.is_square(), "LU requires a square matrix");
        let n = a.rows();
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut num_swaps = 0;
        let scale = a.max_abs().max(EPS);
        for k in 0..n {
            // find pivot row
            let mut p = k;
            let mut pmax = lu[(k, k)].abs();
            for i in (k + 1)..n {
                let v = lu[(i, k)].abs();
                if v > pmax {
                    pmax = v;
                    p = i;
                }
            }
            if pmax <= scale * EPS {
                return Err(Singular { column: k });
            }
            if p != k {
                for j in 0..n {
                    let tmp = lu[(k, j)];
                    lu[(k, j)] = lu[(p, j)];
                    lu[(p, j)] = tmp;
                }
                piv.swap(k, p);
                num_swaps += 1;
            }
            let pivot = lu[(k, k)];
            for i in (k + 1)..n {
                let m = lu[(i, k)] / pivot;
                lu[(i, k)] = m;
                if m == 0.0 {
                    continue;
                }
                for j in (k + 1)..n {
                    let v = lu[(k, j)];
                    lu[(i, j)] -= m * v;
                }
            }
        }
        Ok(Lu { lu, piv, num_swaps })
    }

    /// Solve `A x = b`.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        // apply permutation
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        // forward L (unit diagonal)
        for i in 1..n {
            let mut s = x[i];
            for k in 0..i {
                s -= self.lu[(i, k)] * x[k];
            }
            x[i] = s;
        }
        // backward U
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.lu[(i, k)] * x[k];
            }
            x[i] = s / self.lu[(i, i)];
        }
        x
    }

    /// Solve `A X = B`.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let mut out = Mat::zeros(b.rows(), b.cols());
        for j in 0..b.cols() {
            out.set_col(j, &self.solve_vec(b.col(j)));
        }
        out
    }

    /// Explicit inverse.
    pub fn inverse(&self) -> Mat {
        self.solve_mat(&Mat::eye(self.lu.rows()))
    }

    /// Determinant (product of U diagonal, sign-corrected for row swaps).
    pub fn det(&self) -> f64 {
        let sign = if self.num_swaps % 2 == 0 { 1.0 } else { -1.0 };
        sign * (0..self.lu.rows()).map(|i| self.lu[(i, i)]).product::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn solve_random_system() {
        let mut rng = Rng::new(42);
        let n = 15;
        let a = Mat::from_fn(n, n, |_, _| rng.gauss());
        let xstar: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let b = a.matvec(&xstar);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve_vec(&b);
        let err: f64 = x.iter().zip(&xstar).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
        assert!(err < 1e-9, "err {err}");
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        let a = Mat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve_vec(&[2.0, 3.0]);
        assert!((x[0] - 3.0).abs() < 1e-14 && (x[1] - 2.0).abs() < 1e-14);
    }

    #[test]
    fn det_of_known_matrix() {
        let a = Mat::from_rows(&[&[4.0, 3.0], &[6.0, 3.0]]);
        let lu = Lu::factor(&a).unwrap();
        assert!((lu.det() + 6.0).abs() < 1e-12);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(Lu::factor(&a).is_err());
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let mut rng = Rng::new(9);
        let n = 8;
        let a = Mat::from_fn(n, n, |i, j| rng.gauss() + if i == j { 4.0 } else { 0.0 });
        let inv = Lu::factor(&a).unwrap().inverse();
        let prod = a.matmul(&inv);
        assert!((&prod - &Mat::eye(n)).max_abs() < 1e-10);
    }
}
