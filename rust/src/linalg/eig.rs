//! Cyclic Jacobi eigensolver for symmetric matrices.
//!
//! Used to (a) verify the synthetic spectra of the App. F.1 quadratic
//! experiment, (b) build SPD test matrices with prescribed eigenvalues, and
//! (c) sanity-check conditioning in the diagnostics CLI. Not a hot path.

use super::Mat;

/// Eigendecomposition `A = V diag(w) Vᵀ` of a symmetric matrix.
///
/// Returns `(w, V)` with eigenvalues ascending and eigenvectors in the
/// corresponding columns of `V`.
pub fn sym_eig(a: &Mat) -> (Vec<f64>, Mat) {
    assert!(a.is_square(), "sym_eig requires a square matrix");
    let n = a.rows();
    let mut m = a.symmetrized();
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for j in 0..n {
            for i in 0..j {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-13 * (1.0 + m.max_abs()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/cols p,q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    // sort ascending
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| m[(i, i)].partial_cmp(&m[(j, j)]).unwrap());
    let w: Vec<f64> = idx.iter().map(|&i| m[(i, i)]).collect();
    let mut vs = Mat::zeros(n, n);
    for (new_j, &old_j) in idx.iter().enumerate() {
        for i in 0..n {
            vs[(i, new_j)] = v[(i, old_j)];
        }
    }
    (w, vs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::random_orthogonal;
    use crate::rng::Rng;

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let a = Mat::diag(&[3.0, 1.0, 2.0]);
        let (w, _) = sym_eig(&a);
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert!((w[1] - 2.0).abs() < 1e-12);
        assert!((w[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstructs_symmetric_matrix() {
        let mut rng = Rng::new(14);
        let n = 10;
        let b = Mat::from_fn(n, n, |_, _| rng.gauss());
        let a = b.symmetrized();
        let (w, v) = sym_eig(&a);
        let rec = v.matmul(&Mat::diag(&w)).matmul_t(&v);
        assert!((&rec - &a).max_abs() < 1e-10);
    }

    #[test]
    fn recovers_prescribed_spectrum() {
        let mut rng = Rng::new(2);
        let spec = [0.5, 1.0, 4.0, 9.0, 100.0];
        let q = random_orthogonal(5, &mut rng);
        let a = q.matmul(&Mat::diag(&spec)).matmul_t(&q);
        let (w, _) = sym_eig(&a);
        for (got, want) in w.iter().zip(&spec) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
    }

    #[test]
    fn eigenvectors_satisfy_av_equals_wv() {
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let (w, v) = sym_eig(&a);
        for j in 0..2 {
            let av = a.matvec(v.col(j));
            for i in 0..2 {
                assert!((av[i] - w[j] * v[(i, j)]).abs() < 1e-12);
            }
        }
    }
}
