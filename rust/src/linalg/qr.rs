//! Householder QR and random orthogonal matrices.
//!
//! The HMC experiment of Sec. 5.3 rotates the banana target by "applying a
//! random orthonormal matrix on the input"; we generate those the standard
//! way, as the Q factor of a Gaussian matrix with the sign convention fixed
//! so Q is Haar-distributed.

use super::Mat;
use crate::rng::Rng;

/// Householder QR: returns `(Q, R)` with `Q` orthogonal (`m×m`) and `R`
/// upper triangular (`m×n`), such that `A = Q R`.
pub fn householder_qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows(), a.cols());
    let mut r = a.clone();
    let mut q = Mat::eye(m);
    let steps = n.min(m.saturating_sub(1));
    let mut v = vec![0.0; m];
    for k in 0..steps {
        // Householder vector for column k
        let mut norm = 0.0;
        for i in k..m {
            norm += r[(i, k)] * r[(i, k)];
        }
        let norm = norm.sqrt();
        if norm == 0.0 {
            continue;
        }
        let alpha = if r[(k, k)] >= 0.0 { -norm } else { norm };
        let mut vnorm2 = 0.0;
        for i in k..m {
            v[i] = r[(i, k)];
            if i == k {
                v[i] -= alpha;
            }
            vnorm2 += v[i] * v[i];
        }
        if vnorm2 == 0.0 {
            continue;
        }
        // apply H = I - 2 v vᵀ / (vᵀv) to R (from the left)
        for j in k..n {
            let mut s = 0.0;
            for i in k..m {
                s += v[i] * r[(i, j)];
            }
            let s = 2.0 * s / vnorm2;
            for i in k..m {
                r[(i, j)] -= s * v[i];
            }
        }
        // accumulate into Q (apply H from the right: Q ← Q H)
        for i in 0..m {
            let mut s = 0.0;
            for l in k..m {
                s += q[(i, l)] * v[l];
            }
            let s = 2.0 * s / vnorm2;
            for l in k..m {
                q[(i, l)] -= s * v[l];
            }
        }
    }
    // clean strictly-lower part of R
    for j in 0..n {
        for i in (j + 1)..m {
            r[(i, j)] = 0.0;
        }
    }
    (q, r)
}

/// Haar-distributed random orthogonal `n×n` matrix.
///
/// QR of a Ginibre (iid Gaussian) matrix with the diagonal-sign correction of
/// Mezzadri (2007) so the distribution is exactly Haar.
pub fn random_orthogonal(n: usize, rng: &mut Rng) -> Mat {
    let g = Mat::from_fn(n, n, |_, _| rng.gauss());
    let (mut q, r) = householder_qr(&g);
    for j in 0..n {
        if r[(j, j)] < 0.0 {
            for i in 0..n {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(5);
        let a = Mat::from_fn(7, 5, |_, _| rng.gauss());
        let (q, r) = householder_qr(&a);
        let rec = q.matmul(&r);
        assert!((&rec - &a).max_abs() < 1e-12);
    }

    #[test]
    fn q_is_orthogonal() {
        let mut rng = Rng::new(8);
        let a = Mat::from_fn(6, 6, |_, _| rng.gauss());
        let (q, _) = householder_qr(&a);
        let qtq = q.t_matmul(&q);
        assert!((&qtq - &Mat::eye(6)).max_abs() < 1e-12);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(13);
        let a = Mat::from_fn(6, 4, |_, _| rng.gauss());
        let (_, r) = householder_qr(&a);
        for j in 0..4 {
            for i in (j + 1)..6 {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn random_orthogonal_is_orthogonal() {
        let mut rng = Rng::new(21);
        for n in [2, 5, 30] {
            let q = random_orthogonal(n, &mut rng);
            let qtq = q.t_matmul(&q);
            assert!((&qtq - &Mat::eye(n)).max_abs() < 1e-11, "n={n}");
        }
    }

    #[test]
    fn random_orthogonal_preserves_norms() {
        let mut rng = Rng::new(77);
        let q = random_orthogonal(40, &mut rng);
        let v: Vec<f64> = (0..40).map(|i| (i as f64 * 0.11).sin()).collect();
        let qv = q.matvec(&v);
        let n1: f64 = v.iter().map(|x| x * x).sum();
        let n2: f64 = qv.iter().map(|x| x * x).sum();
        assert!((n1 - n2).abs() < 1e-10 * n1);
    }
}
