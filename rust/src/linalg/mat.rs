//! Column-major dense matrix with the operations the paper's algorithms need.
//!
//! The product kernels here (`matmul*`, `t_matmul*` and the per-column
//! `*_col` helpers) are the **exact reference implementations**: every
//! bit-identity pin in the tree — sharded, remote, chaos, scheduler — is
//! anchored to their summation order, and the `gram.gemm = exact` default
//! runs them verbatim. They deliberately do *not* dispatch on the
//! [`super::gemm`] mode knob; the opt-in blocked fast path lives in
//! [`super::gemm`] and is routed at the [`super::par`]/[`crate::gram`]
//! call sites instead, so `Mat` methods stay a stable oracle for tests.

use std::fmt;
use std::ops::{Add, AddAssign, Index, IndexMut, Mul, Neg, Sub, SubAssign};

/// Dense column-major `rows × cols` matrix of `f64`.
///
/// Column-major is the natural layout here: the data matrices `X, G, Z, V` of
/// the paper are `D×N` with one *data point per column*, and `vec(·)` in all
/// derivations is column stacking, so `Mat::data` *is* `vec(M)`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn full(rows: usize, cols: usize, v: f64) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = d[i];
        }
        m
    }

    /// Build from a column-major data vector (takes ownership).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a row-major slice of slices (test convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            for (j, &v) in row.iter().enumerate() {
                m[(i, j)] = v;
            }
        }
        m
    }

    /// Build entrywise from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Column vector from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Mat { rows: v.len(), cols: 1, data: v.to_vec() }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// The column-major backing store — identical to `vec(self)` of the paper.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the column-major data vector.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Borrow column `j` as a slice.
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.cols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Borrow column `j` mutably.
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.cols);
        let r = self.rows;
        &mut self.data[j * r..(j + 1) * r]
    }

    /// Copy of row `i`.
    pub fn row(&self, i: usize) -> Vec<f64> {
        (0..self.cols).map(|j| self[(i, j)]).collect()
    }

    /// Set column `j` from a slice.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        self.col_mut(j).copy_from_slice(v);
    }

    /// Append a column in place. Column-major layout makes this a plain
    /// `O(rows)` extend — the online conditioning engine leans on it to grow
    /// `D×N` panels without reallocating the retained columns.
    pub fn push_col(&mut self, v: &[f64]) {
        assert_eq!(v.len(), self.rows, "push_col length != rows");
        self.data.extend_from_slice(v);
        self.cols += 1;
    }

    /// Remove the first column in place (`O(rows·cols)` shift) — the
    /// sliding-window drop of the online conditioning engine.
    pub fn remove_first_col(&mut self) {
        assert!(self.cols > 0, "remove_first_col on an empty matrix");
        self.data.drain(..self.rows);
        self.cols -= 1;
    }

    /// Transpose (allocates).
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for j in 0..self.cols {
            for i in 0..self.rows {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Main diagonal as a vector.
    pub fn diagonal(&self) -> Vec<f64> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self[(i, i)]).collect()
    }

    /// Trace.
    pub fn trace(&self) -> f64 {
        self.diagonal().iter().sum()
    }

    /// Matrix product `self * other`, blocked over columns; the `O(N²D)` hot
    /// path of the structured matvec funnels through here.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch {}x{} * {}x{}",
            self.rows, self.cols, other.rows, other.cols);
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// `out = self * other` without allocating. `out` must be pre-shaped.
    ///
    /// Column-major SAXPY ordering: for each output column, accumulate
    /// `A[:,k] * B[k,j]` — unit-stride over `A` and `out`, auto-vectorizes.
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        out.as_mut_slice().fill(0.0);
        self.matmul_acc(other, out);
    }

    /// `out += self * other` (no zeroing) — lets callers fuse several
    /// products into one accumulator buffer (§Perf).
    pub fn matmul_acc(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows);
        assert_eq!(out.rows, self.rows);
        assert_eq!(out.cols, other.cols);
        let m = self.rows;
        for j in 0..other.cols {
            matmul_acc_col(self, other.col(j), &mut out.data[j * m..(j + 1) * m]);
        }
    }

    /// `selfᵀ * other` without materializing the transpose.
    ///
    /// Each output entry is a dot of two columns — unit stride on both sides,
    /// this is the preferred way to form Gram-style products `XᵀΛV`.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.cols, other.cols);
        self.t_matmul_into(other, &mut out);
        out
    }

    /// `out = selfᵀ * other` without allocating. `out` must be pre-shaped
    /// `self.cols × other.cols`.
    pub fn t_matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        assert_eq!(out.rows, self.cols);
        assert_eq!(out.cols, other.cols);
        let m = self.cols;
        for j in 0..other.cols {
            t_matmul_col(self, other.col(j), &mut out.data[j * m..(j + 1) * m]);
        }
    }

    /// `self * otherᵀ` without materializing the transpose.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.rows);
        self.matmul_t_into(other, &mut out);
        out
    }

    /// `out = self * otherᵀ` without allocating. `out` must be pre-shaped
    /// `self.rows × other.rows`.
    ///
    /// Iterates `k` in the outer loop so each column of `self` is streamed
    /// once across all output columns (the transpose-free rank-1 order).
    pub fn matmul_t_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        assert_eq!(out.rows, self.rows);
        assert_eq!(out.cols, other.rows);
        out.data.fill(0.0);
        let m = self.rows;
        for k in 0..self.cols {
            let acol = self.col(k);
            for j in 0..other.rows {
                let bjk = other[(j, k)];
                if bjk == 0.0 {
                    continue;
                }
                let ocol = &mut out.data[j * m..(j + 1) * m];
                for i in 0..m {
                    ocol[i] += acol[i] * bjk;
                }
            }
        }
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        let mut out = vec![0.0; self.rows];
        for (k, &vk) in v.iter().enumerate() {
            if vk == 0.0 {
                continue;
            }
            let acol = self.col(k);
            for i in 0..self.rows {
                out[i] += acol[i] * vk;
            }
        }
        out
    }

    /// `selfᵀ v`.
    pub fn t_matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len());
        (0..self.cols).map(|j| dot(self.col(j), v)).collect()
    }

    /// Hadamard (elementwise) product.
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise division (the `⊘` of App. A).
    pub fn hadamard_div(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a / b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Scale every entry.
    pub fn scale(&self, s: f64) -> Mat {
        let data = self.data.iter().map(|a| a * s).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// In-place `self += s * other` (AXPY).
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|a| a * a).sum::<f64>().sqrt()
    }

    /// Max-abs entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |m, &a| m.max(a.abs()))
    }

    /// Symmetrize: `(self + selfᵀ)/2`.
    pub fn symmetrized(&self) -> Mat {
        assert!(self.is_square());
        Mat::from_fn(self.rows, self.cols, |i, j| 0.5 * (self[(i, j)] + self[(j, i)]))
    }

    /// Extract the contiguous block `rows r0..r0+nr`, `cols c0..c0+nc`.
    pub fn block(&self, r0: usize, c0: usize, nr: usize, nc: usize) -> Mat {
        assert!(r0 + nr <= self.rows && c0 + nc <= self.cols);
        Mat::from_fn(nr, nc, |i, j| self[(r0 + i, c0 + j)])
    }

    /// Write `b` into the block starting at `(r0, c0)`.
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Mat) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols);
        for j in 0..b.cols {
            for i in 0..b.rows {
                self[(r0 + i, c0 + j)] = b[(i, j)];
            }
        }
    }

    /// Horizontal concatenation `[self, other]`.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut data = Vec::with_capacity(self.data.len() + other.data.len());
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Mat { rows: self.rows, cols: self.cols + other.cols, data }
    }

    /// Kronecker product `self ⊗ other` (test oracle only — never in the hot path).
    pub fn kron(&self, other: &Mat) -> Mat {
        let (m, n, p, q) = (self.rows, self.cols, other.rows, other.cols);
        let mut out = Mat::zeros(m * p, n * q);
        for j in 0..n {
            for i in 0..m {
                let a = self[(i, j)];
                if a == 0.0 {
                    continue;
                }
                for jj in 0..q {
                    for ii in 0..p {
                        out[(i * p + ii, j * q + jj)] = a * other[(ii, jj)];
                    }
                }
            }
        }
        out
    }

    /// Map entrywise.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        let data = self.data.iter().map(|&a| f(a)).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Row sums (length `rows`).
    pub fn row_sums(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        for j in 0..self.cols {
            for (i, &v) in self.col(j).iter().enumerate() {
                out[i] += v;
            }
        }
        out
    }

    /// Column sums (length `cols`).
    pub fn col_sums(&self) -> Vec<f64> {
        (0..self.cols).map(|j| self.col(j).iter().sum()).collect()
    }
}

/// Dot product of two slices.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `ocol += a * bcol`, the per-output-column kernel shared by the serial
/// ([`Mat::matmul_acc`]) and parallel ([`super::par`]) product paths.
///
/// 4-wide rank-1 updates: fewer passes over the output column and enough
/// independent FMA chains to keep the vector units busy (§Perf: this alone
/// is ~1.6× on the Fig. 4 matvec).
pub(crate) fn matmul_acc_col(a: &Mat, bcol: &[f64], ocol: &mut [f64]) {
    matmul_acc_col_slice(&a.data, a.rows, a.cols, bcol, ocol);
}

/// Slice-level core of [`matmul_acc_col`]: `a` is a column-major `m×kcols`
/// buffer. Exposed (crate-wide) so the sharded Gram engine can run the
/// *identical* accumulation on borrowed panel slices — bit-identical results
/// across shard counts depend on every path using this one kernel.
pub(crate) fn matmul_acc_col_slice(
    a: &[f64],
    m: usize,
    kcols: usize,
    bcol: &[f64],
    ocol: &mut [f64],
) {
    debug_assert_eq!(a.len(), m * kcols);
    debug_assert_eq!(bcol.len(), kcols);
    debug_assert_eq!(ocol.len(), m);
    let mut k = 0;
    while k + 4 <= kcols {
        let b0 = bcol[k];
        let b1 = bcol[k + 1];
        let b2 = bcol[k + 2];
        let b3 = bcol[k + 3];
        if b0 == 0.0 && b1 == 0.0 && b2 == 0.0 && b3 == 0.0 {
            k += 4;
            continue;
        }
        let (a0, rest) = a[k * m..].split_at(m);
        let (a1, rest) = rest.split_at(m);
        let (a2, rest) = rest.split_at(m);
        let a3 = &rest[..m];
        for i in 0..m {
            ocol[i] += a0[i] * b0 + a1[i] * b1 + a2[i] * b2 + a3[i] * b3;
        }
        k += 4;
    }
    while k < kcols {
        let bkj = bcol[k];
        if bkj != 0.0 {
            let acol = &a[k * m..(k + 1) * m];
            for i in 0..m {
                ocol[i] += acol[i] * bkj;
            }
        }
        k += 1;
    }
}

/// `ocol = aᵀ * bcol`: one output column of the transpose product — every
/// entry a unit-stride column dot.
pub(crate) fn t_matmul_col(a: &Mat, bcol: &[f64], ocol: &mut [f64]) {
    debug_assert_eq!(bcol.len(), a.rows);
    debug_assert_eq!(ocol.len(), a.cols);
    for (i, o) in ocol.iter_mut().enumerate() {
        *o = dot(a.col(i), bcol);
    }
}

/// `ocol += a * bᵀ[:, j]`, i.e. column `j` of `a * bᵀ` accumulated without
/// materializing the transpose (row `j` of `b` gathered on the fly).
pub(crate) fn matmul_t_col(a: &Mat, b: &Mat, j: usize, ocol: &mut [f64]) {
    debug_assert_eq!(ocol.len(), a.rows);
    debug_assert!(j < b.rows);
    for k in 0..a.cols {
        let bjk = b.data[k * b.rows + j];
        if bjk == 0.0 {
            continue;
        }
        let acol = a.col(k);
        for i in 0..ocol.len() {
            ocol[i] += acol[i] * bjk;
        }
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[j * self.rows + i]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[j * self.rows + i]
    }
}

impl Add for &Mat {
    type Output = Mat;
    fn add(self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }
}

impl Sub for &Mat {
    type Output = Mat;
    fn sub(self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }
}

impl Neg for &Mat {
    type Output = Mat;
    fn neg(self) -> Mat {
        self.scale(-1.0)
    }
}

impl Mul for &Mat {
    type Output = Mat;
    fn mul(self, other: &Mat) -> Mat {
        self.matmul(other)
    }
}

impl AddAssign<&Mat> for Mat {
    fn add_assign(&mut self, other: &Mat) {
        self.axpy(1.0, other);
    }
}

impl SubAssign<&Mat> for Mat {
    fn sub_assign(&mut self, other: &Mat) {
        self.axpy(-1.0, other);
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_remove_cols() {
        let mut m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        m.push_col(&[5.0, 6.0]);
        assert_eq!((m.rows(), m.cols()), (2, 3));
        assert_eq!(m.col(2), &[5.0, 6.0]);
        assert_eq!(m[(0, 0)], 1.0);
        m.remove_first_col();
        assert_eq!((m.rows(), m.cols()), (2, 2));
        assert_eq!(m.col(0), &[2.0, 4.0]);
        assert_eq!(m.col(1), &[5.0, 6.0]);
    }

    #[test]
    fn matmul_matches_hand_computed() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn t_matmul_equals_explicit_transpose() {
        let a = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64 * 0.7 - 1.0);
        let b = Mat::from_fn(4, 5, |i, j| (i + 2 * j) as f64 * 0.3);
        let lhs = a.t_matmul(&b);
        let rhs = a.t().matmul(&b);
        assert!((&lhs - &rhs).max_abs() < 1e-14);
    }

    #[test]
    fn matmul_t_equals_explicit_transpose() {
        let a = Mat::from_fn(4, 3, |i, j| (i as f64 - j as f64) * 1.3);
        let b = Mat::from_fn(5, 3, |i, j| (i * j) as f64 + 0.5);
        let lhs = a.matmul_t(&b);
        let rhs = a.matmul(&b.t());
        assert!((&lhs - &rhs).max_abs() < 1e-14);
    }

    #[test]
    fn matvec_consistent_with_matmul() {
        let a = Mat::from_fn(3, 4, |i, j| (i + j) as f64);
        let v = vec![1.0, -1.0, 2.0, 0.5];
        let got = a.matvec(&v);
        let want = a.matmul(&Mat::col_vec(&v));
        assert_eq!(got, want.as_slice());
    }

    #[test]
    fn kron_identity_property() {
        // (A ⊗ B)(C ⊗ D) = AC ⊗ BD
        let a = Mat::from_fn(2, 2, |i, j| (i + 2 * j) as f64 + 1.0);
        let b = Mat::from_fn(3, 3, |i, j| (i as f64) - (j as f64) * 0.5);
        let c = Mat::from_fn(2, 2, |i, j| ((i * j) as f64).sin() + 2.0);
        let d = Mat::from_fn(3, 3, |i, j| ((i + j) as f64).cos());
        let lhs = a.kron(&b).matmul(&c.kron(&d));
        let rhs = a.matmul(&c).kron(&b.matmul(&d));
        assert!((&lhs - &rhs).max_abs() < 1e-12);
    }

    #[test]
    fn kron_vec_identity() {
        // (A ⊗ B) vec(X) = vec(B X Aᵀ) — the workhorse identity of App. A.
        let a = Mat::from_fn(3, 3, |i, j| ((i + j) as f64).exp() / 10.0);
        let b = Mat::from_fn(2, 2, |i, j| (i as f64 + 1.0) * (j as f64 - 0.3));
        let x = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        let lhs = a.kron(&b).matvec(x.as_slice());
        let rhs = b.matmul(&x).matmul_t(&a);
        let diff: f64 =
            lhs.iter().zip(rhs.as_slice()).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-12);
    }

    #[test]
    fn block_roundtrip() {
        let a = Mat::from_fn(5, 6, |i, j| (i * 10 + j) as f64);
        let b = a.block(1, 2, 3, 3);
        let mut c = Mat::zeros(5, 6);
        c.set_block(1, 2, &b);
        assert_eq!(c[(1, 2)], a[(1, 2)]);
        assert_eq!(c[(3, 4)], a[(3, 4)]);
        assert_eq!(c[(0, 0)], 0.0);
    }

    #[test]
    fn row_col_sums() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(a.row_sums(), vec![3.0, 7.0]);
        assert_eq!(a.col_sums(), vec![4.0, 6.0]);
    }

    #[test]
    fn hcat_shapes() {
        let a = Mat::zeros(3, 2);
        let b = Mat::full(3, 4, 1.0);
        let c = a.hcat(&b);
        assert_eq!((c.rows(), c.cols()), (3, 6));
        assert_eq!(c[(0, 3)], 1.0);
        assert_eq!(c[(0, 1)], 0.0);
    }
}
