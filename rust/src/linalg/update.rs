//! Bordered inverse updates for the online conditioning engine.
//!
//! The exact Woodbury engine keeps the explicit inverse of the `N×N`
//! effective derivative matrix `K̂′` around (it is needed *entrywise* to
//! assemble the `N²×N²` core, see [`crate::gram::WoodburySolver`]). When one
//! observation is appended or the oldest is dropped, `K̂′` changes by one
//! bordering row+column, and its inverse follows in `O(N²)` from the block
//! (Schur-complement) inversion formulas instead of an `O(N³)`
//! refactorization:
//!
//! ```text
//! append:  [[A, b],[bᵀ, c]]⁻¹ = [[A⁻¹ + uuᵀ/s, −u/s],[−uᵀ/s, 1/s]],
//!          u = A⁻¹b,  s = c − bᵀA⁻¹b
//! drop:    K⁻¹ = [[e, fᵀ],[f, G]]  ⇒  (K₂₂)⁻¹ = G − ffᵀ/e
//! ```
//!
//! Both return `None` when the pivot (`s` resp. `e`) is numerically
//! degenerate — callers fall back to a cold factorization, which either
//! recovers (pure round-off) or reports the genuine singularity with a
//! proper error.

use super::Mat;

/// Given `A⁻¹` for symmetric `A` (`N×N`), return the inverse of the bordered
/// symmetric matrix `[[A, b],[bᵀ, c]]` in `O(N²)`.
///
/// `None` when the Schur complement `s = c − bᵀA⁻¹b` is non-finite or too
/// small relative to its summands (the bordered matrix is numerically
/// singular, e.g. a duplicated observation).
pub fn bordered_inverse_append(ainv: &Mat, b: &[f64], c: f64) -> Option<Mat> {
    let n = ainv.rows();
    assert!(ainv.is_square(), "A⁻¹ must be square");
    assert_eq!(b.len(), n, "border length != N");
    let u = ainv.matvec(b);
    let btu: f64 = b.iter().zip(&u).map(|(x, y)| x * y).sum();
    let s = c - btu;
    let scale = c.abs() + btu.abs() + 1.0;
    if !s.is_finite() || s.abs() <= 1e-13 * scale {
        return None;
    }
    let sinv = 1.0 / s;
    Some(Mat::from_fn(n + 1, n + 1, |i, j| {
        if i < n && j < n {
            ainv[(i, j)] + sinv * u[i] * u[j]
        } else if i == n && j == n {
            sinv
        } else if i == n {
            -sinv * u[j]
        } else {
            -sinv * u[i]
        }
    }))
}

/// Given `K⁻¹` for symmetric `K` (`(N+1)×(N+1)`), return the inverse of the
/// trailing `N×N` principal submatrix (first row+column dropped) in `O(N²)`.
///
/// `None` when the leading entry `e = (K⁻¹)₀₀` is non-finite or ~0 — by the
/// block-inverse identity `e = 1/(K₀₀ − K₀₁K₂₂⁻¹K₁₀)` it is the reciprocal
/// Schur complement of the dropped pivot, so `e → 0` means the downdate is
/// numerically meaningless.
pub fn bordered_inverse_drop_first(kinv: &Mat) -> Option<Mat> {
    let m = kinv.rows();
    assert!(kinv.is_square() && m > 1, "K⁻¹ must be square with N ≥ 2");
    let e = kinv[(0, 0)];
    if !e.is_finite() || e.abs() < 1e-300 {
        return None;
    }
    let einv = 1.0 / e;
    Some(Mat::from_fn(m - 1, m - 1, |i, j| {
        kinv[(i + 1, j + 1)] - einv * kinv[(i + 1, 0)] * kinv[(j + 1, 0)]
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{random_orthogonal, Lu};
    use crate::rng::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let q = random_orthogonal(n, &mut rng);
        let spec: Vec<f64> = (0..n).map(|i| 0.5 + i as f64).collect();
        q.matmul(&Mat::diag(&spec)).matmul_t(&q)
    }

    #[test]
    fn append_matches_direct_inverse() {
        let n = 6;
        let k = spd(n + 1, 1);
        let a = k.block(0, 0, n, n);
        let b: Vec<f64> = (0..n).map(|i| k[(i, n)]).collect();
        let c = k[(n, n)];
        let ainv = Lu::factor(&a).unwrap().inverse();
        let got = bordered_inverse_append(&ainv, &b, c).unwrap();
        let want = Lu::factor(&k).unwrap().inverse();
        assert!((&got - &want).max_abs() < 1e-10 * (1.0 + want.max_abs()));
    }

    #[test]
    fn drop_first_matches_direct_inverse() {
        let n = 6;
        let k = spd(n + 1, 2);
        let kinv = Lu::factor(&k).unwrap().inverse();
        let got = bordered_inverse_drop_first(&kinv).unwrap();
        let sub = k.block(1, 1, n, n);
        let want = Lu::factor(&sub).unwrap().inverse();
        assert!((&got - &want).max_abs() < 1e-10 * (1.0 + want.max_abs()));
    }

    #[test]
    fn append_then_drop_roundtrips() {
        let n = 5;
        let k = spd(n + 1, 3);
        let kinv = Lu::factor(&k).unwrap().inverse();
        // drop the first row/col, then re-append it at the end: the result
        // must be the inverse of the cyclically permuted matrix.
        let dropped = bordered_inverse_drop_first(&kinv).unwrap();
        let b: Vec<f64> = (1..=n).map(|i| k[(i, 0)]).collect();
        let re = bordered_inverse_append(&dropped, &b, k[(0, 0)]).unwrap();
        let perm = Mat::from_fn(n + 1, n + 1, |i, j| {
            k[((i + 1) % (n + 1), (j + 1) % (n + 1))]
        });
        let want = Lu::factor(&perm).unwrap().inverse();
        assert!((&re - &want).max_abs() < 1e-9 * (1.0 + want.max_abs()));
    }

    #[test]
    fn degenerate_border_is_rejected() {
        // duplicated row/col ⇒ the bordered matrix is singular
        let a = spd(4, 4);
        let ainv = Lu::factor(&a).unwrap().inverse();
        let b: Vec<f64> = (0..4).map(|i| a[(i, 0)]).collect();
        assert!(bordered_inverse_append(&ainv, &b, a[(0, 0)]).is_none());
    }
}
