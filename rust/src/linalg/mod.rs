//! Dense linear-algebra substrate.
//!
//! The paper's algorithms need a small but complete dense toolbox:
//! matrix products (the `O(N²D)` hot path of Eq. 9), Cholesky and LU
//! factorizations (the `N×N` and `N²×N²` solves of App. C.1), Householder QR
//! (random orthogonal matrices for the rotated HMC targets of Sec. 5.3), a
//! Jacobi eigensolver (to verify the synthetic spectra of App. F.1), and a
//! dependency-free parallel product layer ([`par`]) that the structured
//! matvec and the serving path fan out on.
//!
//! Everything is `f64`, column-major, and allocation-explicit so the hot
//! loops in [`crate::gram`] can reuse buffers. The gemm-shaped products run
//! in one of two process-wide modes (the `gram.gemm` knob, see [`gemm`]):
//! in the default `exact` mode the [`par`] kernels reuse the exact serial
//! per-column kernels, so parallel results are bit-identical to serial
//! ones; the opt-in `fast` mode reroutes them through the cache-blocked
//! [`gemm`] core, which trades that cross-mode bit-identity (never the
//! cross-thread/cross-shard one) for several-fold higher flop rates.
//!
//! Orthogonally, the `gram.precision` knob (also in [`gemm`]) adds an
//! opt-in f32 *storage* tier ([`lowp`]) for the large factor panels —
//! storage and transport drop to f32, accumulation stays f64 via widening
//! at pack time, and the solve path recovers f64-quality weights by
//! iterative refinement.

mod chol;
mod eig;
pub mod gemm;
pub mod lowp;
mod lu;
mod mat;
pub mod par;
mod qr;
mod update;

pub use chol::{Cholesky, NotPositiveDefinite};
pub use eig::sym_eig;
pub use lowp::{quantize_f32, MatF32};
pub use lu::Lu;
pub use mat::Mat;
// Per-column product kernels, shared (crate-wide) with the sharded Gram
// engine: bit-identity across shard counts requires every path to run the
// exact same per-column arithmetic.
pub(crate) use mat::{dot as slice_dot, matmul_acc_col_slice};
pub use qr::{householder_qr, random_orthogonal};
pub use update::{bordered_inverse_append, bordered_inverse_drop_first};

/// Machine-epsilon-scaled tolerance used by the factorizations.
pub(crate) const EPS: f64 = 1e-12;
