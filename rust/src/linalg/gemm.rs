//! Cache-blocked f64 gemm — the opt-in *fast* path for the panel products.
//!
//! The paper's `O(N²D + (N²)³)` decomposition makes the `O(N²D)` panel
//! products (`K̂′`/`H`/`(ΛX̃)ᵀ` against RHS blocks) the dominant flop cost,
//! and every layer above — par pool, shards, remote workers, scheduler —
//! bottoms out in the serial per-column kernels of the `mat` module. Those
//! kernels are latency-bound (one running sum per output element), which
//! caps the whole serving stack at a fraction of machine peak. This module
//! is the raw-speed answer: a BLIS-style blocked gemm (idiom: the faer
//! blocked-`matmul` surface) with
//!
//! * **packed panels** — A is repacked into `MR`-row strips, B into
//!   `NR`-column strips, sized by `KC`/`MC`/`NC` so the strips the
//!   microkernel streams stay in L1/L2 instead of striding the full matrix;
//! * **a register-tiled `MR×NR` microkernel** — 32 independent f64
//!   accumulators (8 ymm registers on AVX2) written so the autovectorizer
//!   emits fused multiply-adds; on x86-64 an `avx2+fma` specialization is
//!   selected by runtime feature detection, elsewhere the portable body
//!   relies on the target's native `mul_add`;
//! * **entry points matching the serial surfaces** — [`matmul_into`] /
//!   [`matmul_acc`] / [`t_matmul_into`] / [`matmul_t_into`] mirror the
//!   `Mat` methods of the same names.
//!
//! # Exact vs fast: the mode knob
//!
//! The blocked kernel reassociates the `k`-dimension sum (per `KC` block,
//! fused multiply-add chain), so its results differ from the serial kernels
//! in the last bits. The engine therefore carries two modes ([`GemmMode`]):
//!
//! * `exact` (**default**) — every product runs the serial per-column
//!   kernels. All pre-existing bit-identity pins (sharded / remote / chaos /
//!   scheduler vs the serial reference) hold verbatim.
//! * `fast` — gemm-shaped products ≥ the dispatch sites in
//!   [`super::par`] and [`crate::gram`] run this blocked kernel. Accuracy
//!   contract: entrywise `|fast − exact| ≤ 8·k·ε·(|A|·|B|)` for inner
//!   dimension `k` (standard summation error, pinned by
//!   `tests/gemm_path.rs`); in relative terms ≤ ~1e-12 at serving shapes.
//!
//! **Fast mode is still deterministic.** The arithmetic for one output
//! element depends only on the `k`-dimension blocking (`KC`, a global
//! constant) — never on how the output was partitioned over threads,
//! column blocks, or shard row-blocks, because `m`/`n` partitioning only
//! selects *which* elements a call produces, and zero-padded edge lanes are
//! never written back. Consequently sharded == single-shard and
//! N-thread == 1-thread bit-identity hold *within* fast mode too (proven by
//! the partition-invariance pins in `tests/gemm_path.rs`), and the whole
//! existing pin suite passes under `GDKRON_GEMM=fast` unmodified. What is
//! **not** promised: fast bits matching exact bits, or fast bits matching
//! across machines with different FMA capability. Run every node of a fleet
//! in the same mode.
//!
//! Knob resolution (single source of truth:
//! [`crate::config::resolve_gemm`]): `--gemm` CLI flag > `GDKRON_GEMM` env
//! var > `gram.gemm` config key > `exact`.
//!
//! # The mixed-precision tier ([`Precision`])
//!
//! Orthogonal to the mode knob, `gram.precision = mixed` turns on an **f32
//! storage tier** for the large factor panels (see
//! [`crate::gram::GramFactors`]): panel *storage and transport* drop to
//! f32, while every product still **accumulates in f64** — the f32 operands
//! are widened back to f64 at pack time, so the blocked core below runs the
//! exact same f64 FMA arithmetic with the exact same `KC`-only reduction
//! order. Consequently all within-mode partition/shard/transport
//! bit-identity guarantees carry over to the tier unchanged, and the
//! accuracy contract tightens to storage rounding plus summation error:
//!
//! ```text
//! |mixed − f64| ≤ (1.01·ε_f32 + 8·k·ε_f64) · (|A|·|B|)   entrywise,
//! ```
//!
//! with `ε_f32 = 2⁻²³` (each operand is rounded to nearest once, a ≤ ε_f32/2
//! relative perturbation; the 1% slack covers the cross term). The default
//! `f64` precision is byte-for-byte inert: no tier is built, no dispatch
//! site changes arithmetic.
//!
//! Knob resolution (single source of truth:
//! [`crate::config::resolve_precision`]): `--precision` CLI flag >
//! `GDKRON_PRECISION` env var > `gram.precision` config key > `f64`. Like
//! `GDKRON_GEMM`, the value must be uniform across a fleet — remote shard
//! workers derive their arithmetic from the frames they receive, but a
//! mixed coordinator requires wire-v4 workers (see [`crate::gram::wire`]).

use std::sync::atomic::{AtomicUsize, Ordering};

use super::Mat;

/// Which kernel family the gemm-shaped panel products run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmMode {
    /// Serial per-column reference kernels (the `mat` module). The default;
    /// the ground truth every bit-identity pin is anchored to.
    Exact,
    /// The blocked kernel in this module. Faster, deterministic, and
    /// partition-invariant, but not bit-identical to `Exact`.
    Fast,
}

/// Parse a gemm-mode string (CLI flag, env var or config value): trimmed,
/// case-insensitive `exact` / `fast`. Single source of truth for every
/// spelling of the knob — [`crate::config::resolve_gemm`] and the
/// launcher's `--gemm` flag both route through it.
pub fn parse_gemm_mode(v: &str) -> Option<GemmMode> {
    match v.trim().to_ascii_lowercase().as_str() {
        "exact" => Some(GemmMode::Exact),
        "fast" => Some(GemmMode::Fast),
        _ => None,
    }
}

fn encode(m: GemmMode) -> usize {
    match m {
        GemmMode::Exact => 1,
        GemmMode::Fast => 2,
    }
}

fn decode(v: usize) -> Option<GemmMode> {
    match v {
        1 => Some(GemmMode::Exact),
        2 => Some(GemmMode::Fast),
        _ => None,
    }
}

/// 0 = uninitialized; first [`mode`] call resolves `GDKRON_GEMM`.
static MODE: AtomicUsize = AtomicUsize::new(0);

/// The process-wide gemm mode consulted by every dispatch site.
///
/// Resolution order: last [`set_mode`] call, else `GDKRON_GEMM`, else
/// [`GemmMode::Exact`]. Remote shard workers resolve this independently in
/// their own process — set the env var on every node of a fleet.
pub fn mode() -> GemmMode {
    if let Some(m) = decode(MODE.load(Ordering::Relaxed)) {
        return m;
    }
    let m = std::env::var("GDKRON_GEMM")
        .ok()
        .and_then(|v| parse_gemm_mode(&v))
        .unwrap_or(GemmMode::Exact);
    MODE.store(encode(m), Ordering::Relaxed);
    m
}

/// Set the process-wide gemm mode (overrides the lazy env default).
pub fn set_mode(m: GemmMode) {
    MODE.store(encode(m), Ordering::Relaxed);
}

/// Process-wide CLI override (0 = unset). Mirrors the `--shards` machinery
/// in [`crate::gram::sharded`]: the launcher parses `--gemm` once and
/// installs it here; [`crate::config::resolve_gemm`] gives it top
/// precedence.
static CLI_GEMM: AtomicUsize = AtomicUsize::new(0);

/// Install the `--gemm` CLI override.
pub fn set_global_gemm(m: GemmMode) {
    CLI_GEMM.store(encode(m), Ordering::Relaxed);
}

/// Remove the CLI override (tests).
pub fn clear_global_gemm() {
    CLI_GEMM.store(0, Ordering::Relaxed);
}

/// The CLI override, if one was installed.
pub fn global_gemm() -> Option<GemmMode> {
    decode(CLI_GEMM.load(Ordering::Relaxed))
}

// ---------------------------------------------------------------------------
// The precision knob (see the module doc's mixed-tier section).
// ---------------------------------------------------------------------------

/// Which storage tier the large factor panels live in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// Everything f64, byte-for-byte the pre-knob behaviour. The default.
    F64,
    /// f32 panel storage + transport, f64 accumulation, iterative
    /// refinement on the solve path. Opt-in; error contract in the module
    /// doc and `docs/CONFIG.md`.
    Mixed,
}

/// Parse a precision string (CLI flag, env var or config value): trimmed,
/// case-insensitive `f64` / `mixed`. Single source of truth for every
/// spelling of the knob — [`crate::config::resolve_precision`] and the
/// launcher's `--precision` flag both route through it.
pub fn parse_precision(v: &str) -> Option<Precision> {
    match v.trim().to_ascii_lowercase().as_str() {
        "f64" => Some(Precision::F64),
        "mixed" => Some(Precision::Mixed),
        _ => None,
    }
}

fn encode_precision(p: Precision) -> usize {
    match p {
        Precision::F64 => 1,
        Precision::Mixed => 2,
    }
}

fn decode_precision(v: usize) -> Option<Precision> {
    match v {
        1 => Some(Precision::F64),
        2 => Some(Precision::Mixed),
        _ => None,
    }
}

/// 0 = uninitialized; first [`precision`] call resolves `GDKRON_PRECISION`.
static PRECISION: AtomicUsize = AtomicUsize::new(0);

/// The process-wide panel precision consulted by the tier-construction
/// sites (`GramFactors::rebuild_tier`, the sharded snapshot plumbing, the
/// wire senders). Dispatch inside the kernels is data-driven — they look at
/// whether a tier is *present*, not at this knob — so flipping it only
/// affects factor sets built afterwards.
///
/// Resolution order: last [`set_precision`] call, else `GDKRON_PRECISION`,
/// else [`Precision::F64`].
pub fn precision() -> Precision {
    if let Some(p) = decode_precision(PRECISION.load(Ordering::Relaxed)) {
        return p;
    }
    let p = std::env::var("GDKRON_PRECISION")
        .ok()
        .and_then(|v| parse_precision(&v))
        .unwrap_or(Precision::F64);
    PRECISION.store(encode_precision(p), Ordering::Relaxed);
    p
}

/// Set the process-wide precision (overrides the lazy env default).
pub fn set_precision(p: Precision) {
    PRECISION.store(encode_precision(p), Ordering::Relaxed);
}

/// Process-wide `--precision` CLI override (0 = unset); mirrors
/// [`CLI_GEMM`]. [`crate::config::resolve_precision`] gives it top
/// precedence.
static CLI_PRECISION: AtomicUsize = AtomicUsize::new(0);

/// Install the `--precision` CLI override.
pub fn set_global_precision(p: Precision) {
    CLI_PRECISION.store(encode_precision(p), Ordering::Relaxed);
}

/// Remove the CLI override (tests).
pub fn clear_global_precision() {
    CLI_PRECISION.store(0, Ordering::Relaxed);
}

/// The CLI override, if one was installed.
pub fn global_precision() -> Option<Precision> {
    decode_precision(CLI_PRECISION.load(Ordering::Relaxed))
}

// ---------------------------------------------------------------------------
// Blocking constants.
// ---------------------------------------------------------------------------

/// Microkernel rows: 8 f64 = two ymm vectors per accumulator column.
pub(crate) const MR: usize = 8;
/// Microkernel columns: MR×NR = 32 accumulators = 8 ymm registers, leaving
/// half the AVX2 register file for the A/B streams.
pub(crate) const NR: usize = 4;
/// k-dimension block: one `MR×KC` A-strip (16 KiB) plus one `NR×KC` B-strip
/// (8 KiB) fit L1 together. **Load-bearing for determinism**: per-element
/// arithmetic depends on `KC` and nothing else, so it must stay a global
/// constant — never derived from the shape or the thread count.
pub(crate) const KC: usize = 256;
/// m-dimension block: the packed `MC×KC` A panel (128 KiB) stays L2-resident.
const MC: usize = 64;
/// n-dimension block: bounds the packed B panel (`NC×KC` = 512 KiB).
const NC: usize = 256;

// ---------------------------------------------------------------------------
// Strided views: one packing core serves all four product orientations.
// ---------------------------------------------------------------------------

/// An element type the packing routines can widen to f64. The microkernel
/// and the pack buffers are always f64 — f32 panels are widened **once, at
/// pack time**, so every downstream FMA runs identical f64 arithmetic in
/// the identical `KC` reduction order regardless of the storage tier.
pub(crate) trait PanelElem: Copy + Send + Sync + 'static {
    fn widen(self) -> f64;
}

impl PanelElem for f64 {
    #[inline(always)]
    fn widen(self) -> f64 {
        self
    }
}

impl PanelElem for f32 {
    #[inline(always)]
    fn widen(self) -> f64 {
        self as f64
    }
}

/// A read-only strided matrix view: element `(i, j)` is
/// `data[i*rs + j*cs]`. Column-major `Mat`s are `{rs: 1, cs: rows}`;
/// [`View::transposed`] swaps the strides, which is how the `aᵀ·b` and
/// `a·bᵀ` entry points reuse the same packing routines. The element type
/// defaults to f64; `View<f32>` is the storage-tier variant (widened at
/// pack time, see [`PanelElem`]).
#[derive(Clone, Copy)]
pub(crate) struct View<'a, T = f64> {
    pub data: &'a [T],
    pub rows: usize,
    pub cols: usize,
    pub rs: usize,
    pub cs: usize,
}

impl<'a, T: PanelElem> View<'a, T> {
    /// View over a column-major `rows × cols` slice.
    pub fn col_major(data: &'a [T], rows: usize, cols: usize) -> Self {
        debug_assert!(data.len() >= rows * cols);
        View { data, rows, cols, rs: 1, cs: rows }
    }

    /// The transposed view (no data movement).
    pub fn transposed(self) -> Self {
        View { data: self.data, rows: self.cols, cols: self.rows, rs: self.cs, cs: self.rs }
    }

    /// Columns `j0..j1` of this view (no data movement).
    pub fn col_range(self, j0: usize, j1: usize) -> Self {
        debug_assert!(j0 <= j1 && j1 <= self.cols);
        View { data: &self.data[j0 * self.cs..], rows: self.rows, cols: j1 - j0, ..self }
    }

    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> T {
        self.data[i * self.rs + j * self.cs]
    }
}

impl<'a> View<'a> {
    /// View over a whole `Mat`.
    pub fn of(m: &'a Mat) -> Self {
        View::col_major(m.as_slice(), m.rows(), m.cols())
    }
}

// ---------------------------------------------------------------------------
// Packing.
// ---------------------------------------------------------------------------

/// Pack the `mc × kc` sub-panel of `a` at `(ic, pc)` into `MR`-row strips:
/// strip `s` holds rows `ic + s·MR ..`, laid out `[p·MR + i]` so the
/// microkernel reads `MR` contiguous values per k-step. Rows past `mc` are
/// zero-padded — the padded lanes accumulate garbage-free zeros and are
/// never written back.
fn pack_a<T: PanelElem>(a: View<T>, ic: usize, mc: usize, pc: usize, kc: usize, apack: &mut [f64]) {
    let strips = (mc + MR - 1) / MR;
    for s in 0..strips {
        let i0 = s * MR;
        let rows = MR.min(mc - i0);
        let dst = &mut apack[s * MR * kc..(s + 1) * MR * kc];
        for p in 0..kc {
            let d = &mut dst[p * MR..(p + 1) * MR];
            for i in 0..rows {
                d[i] = a.at(ic + i0 + i, pc + p).widen();
            }
            for v in d.iter_mut().skip(rows) {
                *v = 0.0;
            }
        }
    }
}

/// Pack the `kc × nc` sub-panel of `b` at `(pc, jc)` into `NR`-column
/// strips, laid out `[p·NR + j]`; columns past `nc` are zero-padded.
fn pack_b<T: PanelElem>(b: View<T>, jc: usize, nc: usize, pc: usize, kc: usize, bpack: &mut [f64]) {
    let strips = (nc + NR - 1) / NR;
    for t in 0..strips {
        let j0 = t * NR;
        let cols = NR.min(nc - j0);
        let dst = &mut bpack[t * NR * kc..(t + 1) * NR * kc];
        for p in 0..kc {
            let d = &mut dst[p * NR..(p + 1) * NR];
            for j in 0..cols {
                d[j] = b.at(pc + p, jc + j0 + j).widen();
            }
            for v in d.iter_mut().skip(cols) {
                *v = 0.0;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Microkernel.
// ---------------------------------------------------------------------------

/// `acc[j·MR + i] = fma(ap[p·MR + i], bp[p·NR + j], acc)` over `p < kc`.
/// 32 independent accumulator chains — the autovectorizer turns the inner
/// pair of loops into 8 vfmadd231pd per k-step under `avx2,fma`.
#[inline(always)]
fn micro_fma_body(ap: &[f64], bp: &[f64], kc: usize, acc: &mut [f64; MR * NR]) {
    for p in 0..kc {
        let ar = &ap[p * MR..(p + 1) * MR];
        let br = &bp[p * NR..(p + 1) * NR];
        for j in 0..NR {
            let bv = br[j];
            for i in 0..MR {
                acc[j * MR + i] = ar[i].mul_add(bv, acc[j * MR + i]);
            }
        }
    }
}

/// Same loop with `mul + add` instead of `mul_add`: on x86-64 *without*
/// FMA, `f64::mul_add` lowers to a libm call, which would be slower than
/// the serial kernels it is meant to beat.
#[cfg(target_arch = "x86_64")]
#[inline(always)]
fn micro_mul_body(ap: &[f64], bp: &[f64], kc: usize, acc: &mut [f64; MR * NR]) {
    for p in 0..kc {
        let ar = &ap[p * MR..(p + 1) * MR];
        let br = &bp[p * NR..(p + 1) * NR];
        for j in 0..NR {
            let bv = br[j];
            for i in 0..MR {
                acc[j * MR + i] += ar[i] * bv;
            }
        }
    }
}

/// The `avx2+fma` specialization. The target features let LLVM emit packed
/// vfmadd instead of scalar code or libm fma calls.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn micro_avx2(ap: &[f64], bp: &[f64], kc: usize, acc: &mut [f64; MR * NR]) {
    micro_fma_body(ap, bp, kc, acc)
}

/// Cached runtime CPU-feature probe (0 = unresolved, 1 = yes, 2 = no).
#[cfg(target_arch = "x86_64")]
fn fma_available() -> bool {
    static STATE: AtomicUsize = AtomicUsize::new(0);
    match STATE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let ok = std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma");
            STATE.store(if ok { 1 } else { 2 }, Ordering::Relaxed);
            ok
        }
    }
}

/// Kernel dispatch. The selected body is fixed per machine (runtime
/// detection caches), so fast-mode results are reproducible run-to-run on
/// one host; cross-host bit-identity is not promised in fast mode.
#[inline(always)]
fn micro(ap: &[f64], bp: &[f64], kc: usize, acc: &mut [f64; MR * NR]) {
    #[cfg(target_arch = "x86_64")]
    {
        if fma_available() {
            // SAFETY: avx2+fma presence verified by `fma_available`.
            unsafe { micro_avx2(ap, bp, kc, acc) };
        } else {
            micro_mul_body(ap, bp, kc, acc);
        }
    }
    // aarch64 baseline NEON has native FMA; other targets fall back to
    // whatever `mul_add` lowers to (the fast path is opt-in everywhere).
    #[cfg(not(target_arch = "x86_64"))]
    micro_fma_body(ap, bp, kc, acc);
}

// ---------------------------------------------------------------------------
// The blocked driver.
// ---------------------------------------------------------------------------

/// `c ⟵ a·b` (or `c += a·b` when `accumulate`), `c` column-major
/// `a.rows × b.cols`. The canonical BLIS loop nest: NC columns → KC depth
/// (pack B) → MC rows (pack A) → NR×MR register tiles.
///
/// Determinism contract (load-bearing for every bit-identity pin that runs
/// in fast mode): element `(i, j)` is produced by exactly one microkernel
/// lane per `KC` block, accumulated in increasing-`k` order, regardless of
/// `m`/`n` blocking or which column/row sub-range of a larger product this
/// call covers. See the partition-invariance tests in `tests/gemm_path.rs`.
/// The contract is element-type generic: f32 operands are widened at pack
/// time ([`PanelElem`]), so the `View<f32>` instantiations inherit it
/// verbatim, and the `View<f64>` instantiation is byte-identical to the
/// pre-generic kernel.
pub(crate) fn gemm_view<TA: PanelElem, TB: PanelElem>(
    a: View<TA>,
    b: View<TB>,
    c: &mut [f64],
    accumulate: bool,
) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    assert_eq!(b.rows, k, "gemm inner-dimension mismatch");
    assert_eq!(c.len(), m * n, "gemm output size mismatch");
    if !accumulate {
        for v in c.iter_mut() {
            *v = 0.0;
        }
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let kc_max = KC.min(k);
    let mut apack = vec![0.0; ((MC.min(m) + MR - 1) / MR) * MR * kc_max];
    let mut bpack = vec![0.0; ((NC.min(n) + NR - 1) / NR) * NR * kc_max];
    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b(b, jc, nc, pc, kc, &mut bpack);
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                pack_a(a, ic, mc, pc, kc, &mut apack);
                let mut jr = 0;
                while jr < nc {
                    let nr_eff = NR.min(nc - jr);
                    let bp = &bpack[(jr / NR) * NR * kc..];
                    let mut ir = 0;
                    while ir < mc {
                        let mr_eff = MR.min(mc - ir);
                        let ap = &apack[(ir / MR) * MR * kc..];
                        let mut acc = [0.0f64; MR * NR];
                        micro(ap, bp, kc, &mut acc);
                        // masked writeback: zero-padded edge lanes die here
                        for j in 0..nr_eff {
                            let col = (jc + jr + j) * m + ic + ir;
                            let dst = &mut c[col..col + mr_eff];
                            for i in 0..mr_eff {
                                dst[i] += acc[j * MR + i];
                            }
                        }
                        ir += MR;
                    }
                    jr += NR;
                }
                ic += MC;
            }
            pc += KC;
        }
        jc += NC;
    }
}

// ---------------------------------------------------------------------------
// Entry points mirroring the serial `Mat` surfaces.
// ---------------------------------------------------------------------------

/// Blocked `out = a·b` (shape-checked like [`Mat::matmul_into`]).
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    assert_eq!(out.rows(), a.rows());
    assert_eq!(out.cols(), b.cols());
    let (av, bv) = (View::of(a), View::of(b));
    gemm_view(av, bv, out.as_mut_slice(), false);
}

/// Blocked `out += a·b`.
pub fn matmul_acc(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    assert_eq!(out.rows(), a.rows());
    assert_eq!(out.cols(), b.cols());
    let (av, bv) = (View::of(a), View::of(b));
    gemm_view(av, bv, out.as_mut_slice(), true);
}

/// Blocked `out = aᵀ·b`.
pub fn t_matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.rows(), b.rows(), "t_matmul shape mismatch");
    assert_eq!(out.rows(), a.cols());
    assert_eq!(out.cols(), b.cols());
    let (av, bv) = (View::of(a).transposed(), View::of(b));
    gemm_view(av, bv, out.as_mut_slice(), false);
}

/// Blocked `out = a·bᵀ`.
pub fn matmul_t_into(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols(), b.cols(), "matmul_t shape mismatch");
    assert_eq!(out.rows(), a.rows());
    assert_eq!(out.cols(), b.rows());
    let (av, bv) = (View::of(a), View::of(b).transposed());
    gemm_view(av, bv, out.as_mut_slice(), false);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sample(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.gauss())
    }

    /// Entrywise error budget `8·k·ε·(|A|·|B|)` from the module contract.
    fn err_ok(fast: &Mat, exact: &Mat, abs_prod: &Mat, k: usize) -> bool {
        let mut ok = true;
        for j in 0..fast.cols() {
            for i in 0..fast.rows() {
                let bound = 8.0 * (k.max(1) as f64) * f64::EPSILON * abs_prod[(i, j)].max(1e-300);
                ok &= (fast[(i, j)] - exact[(i, j)]).abs() <= bound;
            }
        }
        ok
    }

    #[test]
    fn parse_accepts_both_modes_case_insensitively() {
        assert_eq!(parse_gemm_mode("exact"), Some(GemmMode::Exact));
        assert_eq!(parse_gemm_mode(" FAST\n"), Some(GemmMode::Fast));
        assert_eq!(parse_gemm_mode("Fast"), Some(GemmMode::Fast));
        assert_eq!(parse_gemm_mode("blocked"), None);
        assert_eq!(parse_gemm_mode(""), None);
    }

    #[test]
    fn cli_override_installs_and_clears() {
        clear_global_gemm();
        assert_eq!(global_gemm(), None);
        set_global_gemm(GemmMode::Fast);
        assert_eq!(global_gemm(), Some(GemmMode::Fast));
        clear_global_gemm();
        assert_eq!(global_gemm(), None);
    }

    #[test]
    fn parse_precision_accepts_both_tiers_case_insensitively() {
        assert_eq!(parse_precision("f64"), Some(Precision::F64));
        assert_eq!(parse_precision(" MIXED\n"), Some(Precision::Mixed));
        assert_eq!(parse_precision("Mixed"), Some(Precision::Mixed));
        assert_eq!(parse_precision("f32"), None);
        assert_eq!(parse_precision(""), None);
    }

    #[test]
    fn precision_cli_override_installs_and_clears() {
        clear_global_precision();
        assert_eq!(global_precision(), None);
        set_global_precision(Precision::Mixed);
        assert_eq!(global_precision(), Some(Precision::Mixed));
        clear_global_precision();
        assert_eq!(global_precision(), None);
    }

    /// Round a matrix to its f32 storage-tier image (column-major).
    fn round32(m: &Mat) -> Vec<f32> {
        m.as_slice().iter().map(|&v| v as f32).collect()
    }

    /// Mixed-tier error budget `(1.01·ε_f32 + 8·k·ε_f64)·(|A|·|B|)` from
    /// the module contract.
    fn mixed_err_ok(mixed: &Mat, exact: &Mat, abs_prod: &Mat, k: usize) -> bool {
        let eps32 = f32::EPSILON as f64;
        let mut ok = true;
        for j in 0..mixed.cols() {
            for i in 0..mixed.rows() {
                let bound = (1.01 * eps32 + 8.0 * (k.max(1) as f64) * f64::EPSILON)
                    * abs_prod[(i, j)].max(1e-300);
                ok &= (mixed[(i, j)] - exact[(i, j)]).abs() <= bound;
            }
        }
        ok
    }

    #[test]
    fn f32_packed_matmul_meets_mixed_bound_vs_f64() {
        for &(m, k, n) in &[(1, 1, 1), (7, 9, 5), (13, 300, 17), (70, 257, 9)] {
            let a = sample(m, k, 59);
            let b = sample(k, n, 61);
            let exact = a.matmul(&b);
            let a32 = round32(&a);
            let av = View::<f32>::col_major(&a32, m, k);
            let mut mixed = Mat::zeros(m, n);
            gemm_view(av, View::of(&b), mixed.as_mut_slice(), false);
            let abs = a.map(f64::abs).matmul(&b.map(f64::abs));
            assert!(mixed_err_ok(&mixed, &exact, &abs, k), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn f32_packed_column_partition_is_bit_invariant() {
        // same invariance the f64 pin rests on, instantiated at View<f32>:
        // within the mixed tier, thread/shard output partitioning must not
        // change a single bit.
        let (m, k, n) = (37, 300, 23);
        let a = sample(m, k, 67);
        let b = sample(k, n, 71);
        let a32 = round32(&a);
        let av = View::<f32>::col_major(&a32, m, k);
        let mut full = Mat::zeros(m, n);
        gemm_view(av, View::of(&b), full.as_mut_slice(), false);
        for split in [0, 1, 7, n] {
            let bv = View::of(&b);
            let mut lo = Mat::zeros(m, split);
            let mut ro = Mat::zeros(m, n - split);
            gemm_view(av, bv.col_range(0, split), lo.as_mut_slice(), false);
            gemm_view(av, bv.col_range(split, n), ro.as_mut_slice(), false);
            let glued = lo.hcat(&ro);
            assert!(glued == full, "split {split} must be bit-identical");
        }
    }

    #[test]
    fn blocked_matmul_matches_serial_within_bound() {
        for &(m, k, n) in &[(1, 1, 1), (7, 9, 5), (13, 300, 17), (65, 64, 3), (70, 257, 9)] {
            let a = sample(m, k, 11);
            let b = sample(k, n, 13);
            let exact = a.matmul(&b);
            let mut fast = Mat::zeros(m, n);
            matmul_into(&a, &b, &mut fast);
            let abs = a.map(f64::abs).matmul(&b.map(f64::abs));
            assert!(err_ok(&fast, &exact, &abs, k), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn transpose_orientations_match_serial_within_bound() {
        let (m, k, n) = (67, 33, 21);
        let a = sample(m, k, 17);
        let b = sample(m, n, 19);
        let mut fast = Mat::zeros(k, n);
        t_matmul_into(&a, &b, &mut fast);
        let abs = a.map(f64::abs).t_matmul(&b.map(f64::abs));
        assert!(err_ok(&fast, &a.t_matmul(&b), &abs, m));

        let c = sample(n, k, 23);
        let mut fast = Mat::zeros(m, n);
        matmul_t_into(&a, &c, &mut fast);
        let abs = a.map(f64::abs).matmul_t(&c.map(f64::abs));
        assert!(err_ok(&fast, &a.matmul_t(&c), &abs, k));
    }

    #[test]
    fn acc_on_zero_seed_is_bitwise_into() {
        let a = sample(19, 70, 29);
        let b = sample(70, 11, 31);
        let mut into = Mat::zeros(19, 11);
        matmul_into(&a, &b, &mut into);
        let mut acc = Mat::zeros(19, 11);
        matmul_acc(&a, &b, &mut acc);
        assert!(into == acc, "into must be zero-fill + acc, bitwise");
    }

    #[test]
    fn column_partition_is_bit_invariant() {
        // the property the fast-mode thread/shard bit-identity pins rest on
        let (m, k, n) = (37, 300, 23);
        let a = sample(m, k, 37);
        let b = sample(k, n, 41);
        let mut full = Mat::zeros(m, n);
        matmul_into(&a, &b, &mut full);
        for split in [0, 1, 7, n] {
            let left = b.block(0, 0, k, split);
            let right = b.block(0, split, k, n - split);
            let mut lo = Mat::zeros(m, split);
            let mut ro = Mat::zeros(m, n - split);
            matmul_into(&a, &left, &mut lo);
            matmul_into(&a, &right, &mut ro);
            let glued = lo.hcat(&ro);
            assert!(glued == full, "split {split} must be bit-identical");
        }
    }

    #[test]
    fn zero_dimension_edges_are_safe() {
        for &(m, k, n) in &[(0, 5, 3), (5, 0, 3), (5, 3, 0), (0, 0, 0)] {
            let a = sample(m, k, 43);
            let b = sample(k, n, 47);
            let mut out = Mat::full(m, n, f64::NAN);
            matmul_into(&a, &b, &mut out);
            assert!(out.as_slice().iter().all(|v| *v == 0.0));
            if k == 0 {
                // acc over an empty inner dim must leave the seed untouched
                let mut seed = sample(m, n, 53);
                let before = seed.clone();
                matmul_acc(&a, &b, &mut seed);
                assert!(seed == before);
            }
        }
    }
}
