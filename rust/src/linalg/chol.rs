//! Cholesky factorization for the symmetric positive-definite solves.
//!
//! All the `N×N` solves of the paper (`K′⁻¹`, `X̃ᵀΛX̃⁻¹`, `G̃ᵀΛG̃⁻¹`, …) and the
//! `N²×N²` Woodbury core are SPD (or symmetrized SPD) systems, so Cholesky is
//! the workhorse factorization of the whole library.

use super::{Mat, EPS};

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Mat,
}

/// Error raised when the matrix is not (numerically) positive definite.
#[derive(Debug, Clone, PartialEq)]
pub struct NotPositiveDefinite {
    /// Pivot index at which the factorization broke down.
    pub pivot: usize,
    /// Value of the offending pivot.
    pub value: f64,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix not positive definite: pivot {} = {:.3e}", self.pivot, self.value)
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl Cholesky {
    /// Factor an SPD matrix. Fails with [`NotPositiveDefinite`] on a
    /// non-positive pivot (relative to the largest diagonal entry).
    pub fn factor(a: &Mat) -> Result<Self, NotPositiveDefinite> {
        assert!(a.is_square(), "Cholesky requires a square matrix");
        let n = a.rows();
        let mut l = a.clone();
        let scale = (0..n).map(|i| a[(i, i)].abs()).fold(1.0_f64, f64::max);
        for j in 0..n {
            // pivot
            let mut d = l[(j, j)];
            for k in 0..j {
                d -= l[(j, k)] * l[(j, k)];
            }
            if d <= scale * EPS {
                return Err(NotPositiveDefinite { pivot: j, value: d });
            }
            let d = d.sqrt();
            l[(j, j)] = d;
            // column below the pivot
            for i in (j + 1)..n {
                let mut s = l[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                l[(i, j)] = s / d;
            }
        }
        // zero the strict upper triangle
        for j in 1..n {
            for i in 0..j {
                l[(i, j)] = 0.0;
            }
        }
        Ok(Cholesky { l })
    }

    /// Factor with a diagonal jitter fallback: tries `A`, then
    /// `A + jitter·scale·I` with geometrically growing jitter. Used by the GP
    /// layer where round-off can push tiny eigenvalues slightly negative.
    pub fn factor_with_jitter(
        a: &Mat,
        max_tries: usize,
    ) -> Result<(Self, f64), NotPositiveDefinite> {
        match Self::factor(a) {
            Ok(c) => return Ok((c, 0.0)),
            Err(e) if max_tries == 0 => return Err(e),
            Err(_) => {}
        }
        let n = a.rows();
        let scale = (0..n).map(|i| a[(i, i)].abs()).fold(EPS, f64::max);
        let mut jitter = 1e-10 * scale;
        let mut last = NotPositiveDefinite { pivot: 0, value: 0.0 };
        for _ in 0..max_tries {
            let mut aj = a.clone();
            for i in 0..n {
                aj[(i, i)] += jitter;
            }
            match Self::factor(&aj) {
                Ok(c) => return Ok((c, jitter)),
                Err(e) => last = e,
            }
            jitter *= 10.0;
        }
        Err(last)
    }

    /// The lower factor `L`.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Solve `A x = b` in place for a single right-hand side.
    pub fn solve_vec_in_place(&self, b: &mut [f64]) {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        // forward: L y = b
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l[(i, k)] * b[k];
            }
            b[i] = s / self.l[(i, i)];
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = b[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * b[k];
            }
            b[i] = s / self.l[(i, i)];
        }
    }

    /// Solve `A x = b`.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_vec_in_place(&mut x);
        x
    }

    /// Solve `A X = B` column by column.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows(), self.l.rows());
        let mut out = b.clone();
        for j in 0..b.cols() {
            self.solve_vec_in_place(out.col_mut(j));
        }
        out
    }

    /// Explicit inverse (only used for `Λ⁻¹`-style small matrices and tests).
    pub fn inverse(&self) -> Mat {
        self.solve_mat(&Mat::eye(self.l.rows()))
    }

    /// log-determinant of `A` (twice the log of the diagonal product of `L`).
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let b = Mat::from_fn(n, n, |_, _| rng.gauss());
        let mut a = b.t_matmul(&b);
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd(8, 7);
        let c = Cholesky::factor(&a).unwrap();
        let rec = c.l().matmul_t(c.l());
        assert!((&rec - &a).max_abs() < 1e-10);
    }

    #[test]
    fn solve_residual_small() {
        let a = spd(12, 3);
        let c = Cholesky::factor(&a).unwrap();
        let b: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let x = c.solve_vec(&b);
        let r = a.matvec(&x);
        let err: f64 = r.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
        assert!(err < 1e-9, "residual {err}");
    }

    #[test]
    fn solve_mat_matches_columns() {
        let a = spd(6, 11);
        let c = Cholesky::factor(&a).unwrap();
        let b = Mat::from_fn(6, 3, |i, j| ((i + j) as f64).cos());
        let x = c.solve_mat(&b);
        let rec = a.matmul(&x);
        assert!((&rec - &b).max_abs() < 1e-9);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn jitter_recovers_semidefinite() {
        // rank-1 PSD matrix: plain Cholesky fails, jitter path succeeds.
        let v = Mat::col_vec(&[1.0, 2.0, 3.0]);
        let a = v.matmul_t(&v);
        assert!(Cholesky::factor(&a).is_err());
        let (c, jitter) = Cholesky::factor_with_jitter(&a, 12).unwrap();
        assert!(jitter > 0.0);
        let rec = c.l().matmul_t(c.l());
        assert!((&rec - &a).max_abs() < 1e-3);
    }

    #[test]
    fn log_det_matches_known() {
        let a = Mat::diag(&[2.0, 3.0, 4.0]);
        let c = Cholesky::factor(&a).unwrap();
        assert!((c.log_det() - (24.0_f64).ln()).abs() < 1e-12);
    }
}
