//! Dependency-free parallel matrix products over a scoped thread pool.
//!
//! The paper's throughput story is `O(N²D)` structured matvecs; at serving
//! scale (D ≥ 10², many queries per batch) those are gemm-shaped and
//! embarrassingly parallel over output columns. The environment has no
//! rayon, so this module partitions output columns into contiguous blocks
//! and fans them out over `std::thread::scope` workers — each worker owns a
//! disjoint column range of the output buffer (`chunks_mut`), so there is no
//! sharing, no locking, and bit-identical results to the serial kernels
//! (same per-column kernel, same summation order).
//!
//! Knobs:
//! * [`set_threads`] / [`threads`] — process-wide worker count. The first
//!   read initializes from the `GDKRON_THREADS` environment variable, else
//!   from `std::thread::available_parallelism`. `threads = 1` is the serial
//!   fallback: no threads are spawned at all.
//! * Small products stay serial regardless ([`MIN_PAR_FLOPS`]): a spawn
//!   costs ~10µs, so parallelism must clear that bar to pay off.
//!
//! The `*_with` variants take an explicit thread count (used by the property
//! tests to force the parallel path on tiny shapes, and by benches to sweep
//! scaling).

use std::sync::atomic::{AtomicUsize, Ordering};

use super::mat::{matmul_acc_col, matmul_t_col, t_matmul_col};
use super::Mat;

/// Upper bound on the worker count (sanity clamp for bad env values).
pub const MAX_THREADS: usize = 256;

/// Products below this many flops (`2·m·k·n`) run serially: thread spawn
/// latency would dominate.
pub const MIN_PAR_FLOPS: usize = 1 << 17;

/// 0 = uninitialized; first [`threads`] call resolves the default.
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Parse a thread-count string (CLI flag, env var or config value): trimmed
/// integer, clamped to `1..=MAX_THREADS` (so `0` means the serial
/// fallback). Single source of truth for every spelling of the knob —
/// [`crate::config::resolve_threads`] and the launcher's `--threads` flag
/// both route through it.
pub fn parse_threads(v: &str) -> Option<usize> {
    v.trim().parse::<usize>().ok().map(|n| n.clamp(1, MAX_THREADS))
}

fn env_threads() -> Option<usize> {
    parse_threads(&std::env::var("GDKRON_THREADS").ok()?)
}

/// The process-wide worker count for parallel linalg.
///
/// Resolution order: last [`set_threads`] call, else `GDKRON_THREADS`, else
/// the machine's available parallelism.
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let t = env_threads().unwrap_or_else(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(MAX_THREADS)
    });
    THREADS.store(t, Ordering::Relaxed);
    t
}

/// Set the process-wide worker count (clamped to `1..=MAX_THREADS`).
/// `1` disables parallelism entirely (serial fallback).
pub fn set_threads(n: usize) {
    THREADS.store(n.clamp(1, MAX_THREADS), Ordering::Relaxed);
}

/// Effective worker count for a product of `flops` total work spread over
/// `cols` independent output columns. Beyond the on/off threshold, the
/// worker count is bounded so each worker clears ~[`MIN_PAR_FLOPS`] of work
/// — spawning the whole pool on a product barely above the threshold would
/// pay more in spawn latency than it wins.
fn effective_threads(flops: usize, cols: usize) -> usize {
    if flops < MIN_PAR_FLOPS || cols < 2 {
        return 1;
    }
    threads().min(cols).min((flops / MIN_PAR_FLOPS).max(1))
}

/// Run `f(j, column_j)` for every column of `out`, fanned out over
/// `nthreads` scoped workers in contiguous column blocks. `nthreads <= 1`
/// runs inline on the caller's thread.
///
/// This is the fork-join primitive behind every parallel product here, and
/// it is public because higher layers reuse it for per-column work that is
/// not a matmul (e.g. batched GP prediction in [`crate::gp`]).
pub fn par_columns<F>(out: &mut Mat, nthreads: usize, f: F)
where
    F: Fn(usize, &mut [f64]) + Sync,
{
    let m = out.rows();
    let cols = out.cols();
    if cols == 0 {
        return;
    }
    let t = nthreads.clamp(1, cols);
    if t == 1 || m == 0 {
        for j in 0..cols {
            f(j, out.col_mut(j));
        }
        return;
    }
    // ceil so every worker gets a block and the last may run short
    let block = (cols + t - 1) / t;
    let fref = &f;
    std::thread::scope(|s| {
        let mut chunks = out.as_mut_slice().chunks_mut(block * m).enumerate();
        // the caller works too: keep the first block inline (one fewer
        // spawn, no idle core blocked in the join)
        let first = chunks.next();
        for (ci, chunk) in chunks {
            let j0 = ci * block;
            s.spawn(move || {
                for (dj, col) in chunk.chunks_mut(m).enumerate() {
                    fref(j0 + dj, col);
                }
            });
        }
        if let Some((_, chunk)) = first {
            for (dj, col) in chunk.chunks_mut(m).enumerate() {
                fref(dj, col);
            }
        }
    });
}

/// `out = a * b`, parallel over output columns (auto thread count).
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    let t = effective_threads(2 * a.rows() * a.cols() * b.cols(), b.cols());
    matmul_into_with(a, b, out, t);
}

/// `out = a * b` with an explicit worker count.
pub fn matmul_into_with(a: &Mat, b: &Mat, out: &mut Mat, nthreads: usize) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    assert_eq!(out.rows(), a.rows());
    assert_eq!(out.cols(), b.cols());
    par_columns(out, nthreads, |j, col| {
        col.fill(0.0);
        matmul_acc_col(a, b.col(j), col);
    });
}

/// `out += a * b`, parallel over output columns (auto thread count).
pub fn matmul_acc(a: &Mat, b: &Mat, out: &mut Mat) {
    let t = effective_threads(2 * a.rows() * a.cols() * b.cols(), b.cols());
    matmul_acc_with(a, b, out, t);
}

/// `out += a * b` with an explicit worker count.
pub fn matmul_acc_with(a: &Mat, b: &Mat, out: &mut Mat, nthreads: usize) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    assert_eq!(out.rows(), a.rows());
    assert_eq!(out.cols(), b.cols());
    par_columns(out, nthreads, |j, col| {
        matmul_acc_col(a, b.col(j), col);
    });
}

/// `a * b` allocating, parallel over output columns.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut out);
    out
}

/// `out = aᵀ * b`, parallel over output columns (auto thread count).
pub fn t_matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    let t = effective_threads(2 * a.rows() * a.cols() * b.cols(), b.cols());
    t_matmul_into_with(a, b, out, t);
}

/// `out = aᵀ * b` with an explicit worker count.
pub fn t_matmul_into_with(a: &Mat, b: &Mat, out: &mut Mat, nthreads: usize) {
    assert_eq!(a.rows(), b.rows(), "t_matmul shape mismatch");
    assert_eq!(out.rows(), a.cols());
    assert_eq!(out.cols(), b.cols());
    par_columns(out, nthreads, |j, col| {
        t_matmul_col(a, b.col(j), col);
    });
}

/// `aᵀ * b` allocating, parallel over output columns.
pub fn t_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.cols(), b.cols());
    t_matmul_into(a, b, &mut out);
    out
}

/// `out = a * bᵀ`, parallel over output columns (auto thread count).
pub fn matmul_t_into(a: &Mat, b: &Mat, out: &mut Mat) {
    let t = effective_threads(2 * a.rows() * a.cols() * b.rows(), b.rows());
    matmul_t_into_with(a, b, out, t);
}

/// `out = a * bᵀ` with an explicit worker count.
pub fn matmul_t_into_with(a: &Mat, b: &Mat, out: &mut Mat, nthreads: usize) {
    assert_eq!(a.cols(), b.cols(), "matmul_t shape mismatch");
    assert_eq!(out.rows(), a.rows());
    assert_eq!(out.cols(), b.rows());
    par_columns(out, nthreads, |j, col| {
        col.fill(0.0);
        matmul_t_col(a, b, j, col);
    });
}

/// `a * bᵀ` allocating, parallel over output columns.
pub fn matmul_t(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows(), b.rows());
    matmul_t_into(a, b, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sample(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.gauss())
    }

    #[test]
    fn knob_clamps_and_persists() {
        let before = threads();
        set_threads(0);
        assert_eq!(threads(), 1);
        set_threads(4);
        assert_eq!(threads(), 4);
        set_threads(before);
    }

    #[test]
    fn forced_parallel_matches_serial_small() {
        let a = sample(7, 5, 1);
        let b = sample(5, 9, 2);
        let want = a.matmul(&b);
        let mut got = Mat::zeros(7, 9);
        matmul_into_with(&a, &b, &mut got, 4);
        assert!((&got - &want).max_abs() == 0.0, "parallel path must be bit-identical");
    }

    #[test]
    fn par_columns_covers_every_column_once() {
        let mut out = Mat::zeros(3, 10);
        par_columns(&mut out, 4, |j, col| {
            for v in col.iter_mut() {
                *v += (j + 1) as f64;
            }
        });
        for j in 0..10 {
            for i in 0..3 {
                assert_eq!(out[(i, j)], (j + 1) as f64, "col {j}");
            }
        }
    }

    #[test]
    fn zero_sized_outputs_are_noops() {
        let a = sample(4, 3, 3);
        let b = Mat::zeros(3, 0);
        let mut out = Mat::zeros(4, 0);
        matmul_into_with(&a, &b, &mut out, 4);
        let a0 = Mat::zeros(0, 3);
        let mut out0 = Mat::zeros(0, 5);
        matmul_into_with(&a0, &sample(3, 5, 4), &mut out0, 4);
    }
}
