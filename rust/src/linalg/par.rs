//! Dependency-free parallel matrix products over a scoped thread pool.
//!
//! The paper's throughput story is `O(N²D)` structured matvecs; at serving
//! scale (D ≥ 10², many queries per batch) those are gemm-shaped and
//! embarrassingly parallel over output columns. The environment has no
//! rayon, so this module partitions output columns into contiguous blocks
//! and fans them out over `std::thread::scope` workers — each worker owns a
//! disjoint column range of the output buffer (`chunks_mut`), so there is no
//! sharing and no locking.
//!
//! Every product dispatches on the process-wide [`gemm::mode`] knob:
//!
//! * **exact** (default) — each worker runs the serial per-column kernels of
//!   the `mat` module on its columns, so parallel results are bit-identical to
//!   the serial reference (same per-column kernel, same summation order).
//! * **fast** — each worker runs the cache-blocked [`gemm`] kernel on its
//!   whole column block (the blocked tile, not the single column, is the
//!   per-thread work unit). Still bit-identical across thread counts —
//!   the blocked kernel's per-element arithmetic is invariant under output
//!   partitioning (see [`gemm`]) — but *not* bit-identical to exact mode.
//!
//! Knobs:
//! * [`set_threads`] / [`threads`] — process-wide worker count. The first
//!   read initializes from the `GDKRON_THREADS` environment variable, else
//!   from `std::thread::available_parallelism`. `threads = 1` is the serial
//!   fallback: no threads are spawned at all.
//! * Small products stay serial regardless ([`MIN_PAR_FLOPS`] /
//!   [`MIN_PAR_FLOPS_FAST`]): a spawn costs ~10µs, so parallelism must
//!   clear that bar to pay off.
//!
//! The `*_with` variants take an explicit thread count (used by the property
//! tests to force the parallel path on tiny shapes, and by benches to sweep
//! scaling).

use std::sync::atomic::{AtomicUsize, Ordering};

use super::gemm::{self, GemmMode, PanelElem, View};
use super::mat::{matmul_acc_col, matmul_t_col, t_matmul_col};
use super::{Mat, MatF32};

/// Upper bound on the worker count (sanity clamp for bad env values).
pub const MAX_THREADS: usize = 256;

/// Exact-mode products below this many flops (`2·m·k·n`) run serially:
/// thread spawn latency would dominate.
///
/// Derivation (re-measure on target hardware with
/// `cargo bench --bench gemm_kernels -- --crossover`, which sweeps product
/// sizes through both serial and forced-parallel dispatch and prints the
/// observed break-even): a `std::thread::scope` spawn+join round trip costs
/// ~10 µs, and the exact per-column kernels sustain roughly 3 GFLOP/s on a
/// single core, so 2¹⁷ flops ≈ 40 µs of serial work ≈ 4 spawn costs —
/// enough that handing half of it to one extra worker wins even after
/// paying the spawn. Below that the spawn eats the savings.
pub const MIN_PAR_FLOPS: usize = 1 << 17;

/// Fast-mode serial/parallel crossover. The blocked kernel sustains ~4× the
/// exact per-column flop rate (FMA microkernel vs latency-bound column
/// sums), so the same ~4-spawn-cost break-even sits 4× more flops up.
/// Re-measure alongside [`MIN_PAR_FLOPS`] with the `--crossover` sweep.
pub const MIN_PAR_FLOPS_FAST: usize = 1 << 19;

/// 0 = uninitialized; first [`threads`] call resolves the default.
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Parse a thread-count string (CLI flag, env var or config value): trimmed
/// integer, clamped to `1..=MAX_THREADS` (so `0` means the serial
/// fallback). Single source of truth for every spelling of the knob —
/// [`crate::config::resolve_threads`] and the launcher's `--threads` flag
/// both route through it.
pub fn parse_threads(v: &str) -> Option<usize> {
    v.trim().parse::<usize>().ok().map(|n| n.clamp(1, MAX_THREADS))
}

fn env_threads() -> Option<usize> {
    parse_threads(&std::env::var("GDKRON_THREADS").ok()?)
}

/// The process-wide worker count for parallel linalg.
///
/// Resolution order: last [`set_threads`] call, else `GDKRON_THREADS`, else
/// the machine's available parallelism.
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let t = env_threads().unwrap_or_else(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(MAX_THREADS)
    });
    THREADS.store(t, Ordering::Relaxed);
    t
}

/// Set the process-wide worker count (clamped to `1..=MAX_THREADS`).
/// `1` disables parallelism entirely (serial fallback).
pub fn set_threads(n: usize) {
    THREADS.store(n.clamp(1, MAX_THREADS), Ordering::Relaxed);
}

/// Effective worker count for a product of `flops` total work spread over
/// `cols` independent output columns. Beyond the on/off threshold, the
/// worker count is bounded so each worker clears ~one crossover quantum of
/// work — spawning the whole pool on a product barely above the threshold
/// would pay more in spawn latency than it wins. The quantum is
/// mode-dependent: the fast kernel burns flops quicker, so it needs more of
/// them per worker to amortize a spawn.
fn effective_threads(flops: usize, cols: usize, mode: GemmMode) -> usize {
    let quantum = match mode {
        GemmMode::Exact => MIN_PAR_FLOPS,
        GemmMode::Fast => MIN_PAR_FLOPS_FAST,
    };
    if flops < quantum || cols < 2 {
        return 1;
    }
    threads().min(cols).min((flops / quantum).max(1))
}

/// Run `f(j, column_j)` for every column of `out`, fanned out over
/// `nthreads` scoped workers in contiguous column blocks. `nthreads <= 1`
/// runs inline on the caller's thread.
///
/// This is the fork-join primitive behind every parallel product here, and
/// it is public because higher layers reuse it for per-column work that is
/// not a matmul (e.g. batched GP prediction in [`crate::gp`]).
pub fn par_columns<F>(out: &mut Mat, nthreads: usize, f: F)
where
    F: Fn(usize, &mut [f64]) + Sync,
{
    let m = out.rows();
    let cols = out.cols();
    if cols == 0 {
        return;
    }
    let t = nthreads.clamp(1, cols);
    if t == 1 || m == 0 {
        for j in 0..cols {
            f(j, out.col_mut(j));
        }
        return;
    }
    // ceil so every worker gets a block and the last may run short
    let block = (cols + t - 1) / t;
    let fref = &f;
    std::thread::scope(|s| {
        let mut chunks = out.as_mut_slice().chunks_mut(block * m).enumerate();
        // the caller works too: keep the first block inline (one fewer
        // spawn, no idle core blocked in the join)
        let first = chunks.next();
        for (ci, chunk) in chunks {
            let j0 = ci * block;
            s.spawn(move || {
                for (dj, col) in chunk.chunks_mut(m).enumerate() {
                    fref(j0 + dj, col);
                }
            });
        }
        if let Some((_, chunk)) = first {
            for (dj, col) in chunk.chunks_mut(m).enumerate() {
                fref(dj, col);
            }
        }
    });
}

/// The three gemm-shaped product orientations the engine uses. One enum +
/// one driver replaces the four near-identical dispatch loops that used to
/// live here — the shape checks, the exact-vs-fast split and the column
/// fan-out now exist exactly once.
#[derive(Clone, Copy)]
enum Kind {
    /// `out ⟵ a·b`
    Mul,
    /// `out ⟵ aᵀ·b`
    TMul,
    /// `out ⟵ a·bᵀ`
    MulT,
}

impl Kind {
    /// Shape-check `a`/`b`/`out` and return the product's total flops.
    fn check(self, a: &Mat, b: &Mat, out: &Mat) -> usize {
        match self {
            Kind::Mul => {
                assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
                assert_eq!(out.rows(), a.rows());
                assert_eq!(out.cols(), b.cols());
            }
            Kind::TMul => {
                assert_eq!(a.rows(), b.rows(), "t_matmul shape mismatch");
                assert_eq!(out.rows(), a.cols());
                assert_eq!(out.cols(), b.cols());
            }
            Kind::MulT => {
                assert_eq!(a.cols(), b.cols(), "matmul_t shape mismatch");
                assert_eq!(out.rows(), a.rows());
                assert_eq!(out.cols(), b.rows());
            }
        }
        2 * a.rows() * a.cols() * out.cols()
    }
}

/// The shared driver behind every public product: shape checks, then the
/// exact-vs-fast split, then the column fan-out. `accumulate` is only
/// meaningful for [`Kind::Mul`] (the only orientation with a public `acc`
/// surface).
fn product(
    kind: Kind,
    accumulate: bool,
    a: &Mat,
    b: &Mat,
    out: &mut Mat,
    t: usize,
    mode: GemmMode,
) {
    debug_assert!(!accumulate || matches!(kind, Kind::Mul));
    match mode {
        GemmMode::Exact => par_columns(out, t, |j, col| match kind {
            Kind::Mul => {
                if !accumulate {
                    col.fill(0.0);
                }
                matmul_acc_col(a, b.col(j), col);
            }
            Kind::TMul => t_matmul_col(a, b.col(j), col),
            Kind::MulT => {
                col.fill(0.0);
                matmul_t_col(a, b, j, col);
            }
        }),
        GemmMode::Fast => fast_product(kind, accumulate, a, b, out, t),
    }
}

/// Fast-mode fan-out: contiguous column blocks of `out` are the per-thread
/// work units, each computed by one blocked-gemm call over the matching
/// column (Mul/TMul) or row (MulT) range of `b`. Because the blocked
/// kernel's per-element arithmetic is invariant under output partitioning
/// (see [`gemm`]), the result is bit-identical for every thread count.
fn fast_product(kind: Kind, accumulate: bool, a: &Mat, b: &Mat, out: &mut Mat, nthreads: usize) {
    let m = out.rows();
    let cols = out.cols();
    if cols == 0 {
        return;
    }
    let bview = match kind {
        Kind::Mul | Kind::TMul => View::of(b),
        Kind::MulT => View::of(b).transposed(),
    };
    let run = |j0: usize, j1: usize, chunk: &mut [f64]| {
        let av = match kind {
            Kind::Mul | Kind::MulT => View::of(a),
            Kind::TMul => View::of(a).transposed(),
        };
        gemm::gemm_view(av, bview.col_range(j0, j1), chunk, accumulate);
    };
    let t = nthreads.clamp(1, cols);
    if t == 1 || m == 0 {
        run(0, cols, out.as_mut_slice());
        return;
    }
    let block = (cols + t - 1) / t;
    let runref = &run;
    std::thread::scope(|s| {
        let mut chunks = out.as_mut_slice().chunks_mut(block * m).enumerate();
        let first = chunks.next();
        for (ci, chunk) in chunks {
            let j0 = ci * block;
            let j1 = j0 + chunk.len() / m;
            s.spawn(move || runref(j0, j1, chunk));
        }
        if let Some((_, chunk)) = first {
            runref(0, chunk.len() / m, chunk);
        }
    });
}

// ---------------------------------------------------------------------------
// Mixed-tier fan-outs (gram.precision = mixed).
//
// Same contiguous-column-block partitioning as `fast_product`, same blocked
// kernel underneath — so the same thread-count bit-invariance argument
// applies verbatim — but generic over the operand element types, which is
// how the f32 storage tier flows into an all-f64 accumulation. Kept
// separate from `fast_product` on purpose: the f64 fast path must stay
// byte-identical to its pre-tier self.
// ---------------------------------------------------------------------------

/// Blocked fan-out over contiguous column blocks of `out`. `av`/`bview`
/// are the full product operands; each worker computes one column range of
/// `out` from the matching `col_range` of `bview`.
fn blocked_fan_out<TA: PanelElem, TB: PanelElem>(
    av: View<TA>,
    bview: View<TB>,
    out: &mut Mat,
    accumulate: bool,
    nthreads: usize,
) {
    let m = out.rows();
    let cols = out.cols();
    if cols == 0 {
        return;
    }
    let t = nthreads.clamp(1, cols);
    if t == 1 || m == 0 {
        gemm::gemm_view(av, bview, out.as_mut_slice(), accumulate);
        return;
    }
    let block = (cols + t - 1) / t;
    std::thread::scope(|s| {
        let mut chunks = out.as_mut_slice().chunks_mut(block * m).enumerate();
        let first = chunks.next();
        for (ci, chunk) in chunks {
            let j0 = ci * block;
            let j1 = j0 + chunk.len() / m;
            s.spawn(move || gemm::gemm_view(av, bview.col_range(j0, j1), chunk, accumulate));
        }
        if let Some((_, chunk)) = first {
            gemm::gemm_view(av, bview.col_range(0, chunk.len() / m), chunk, accumulate);
        }
    });
}

/// `out = a32 · b` (or `out += a32 · b` when `accumulate`): f32
/// storage-tier left operand, widened at pack time, f64 accumulation.
/// Thread count uses the fast-mode quantum — it is the same blocked kernel.
pub fn mixed_matmul_into(a32: &MatF32, b: &Mat, out: &mut Mat, accumulate: bool) {
    assert_eq!(a32.cols(), b.rows(), "mixed matmul shape mismatch");
    assert_eq!(out.rows(), a32.rows());
    assert_eq!(out.cols(), b.cols());
    let flops = 2 * a32.rows() * a32.cols() * out.cols();
    let t = effective_threads(flops, out.cols(), GemmMode::Fast);
    blocked_fan_out(a32.view(), View::of(b), out, accumulate, t);
}

/// `out = aᵀ · b32`: f64 transposed left operand against an f32
/// storage-tier right operand.
pub fn mixed_t_matmul_into(a: &Mat, b32: &MatF32, out: &mut Mat) {
    assert_eq!(a.rows(), b32.rows(), "mixed t_matmul shape mismatch");
    assert_eq!(out.rows(), a.cols());
    assert_eq!(out.cols(), b32.cols());
    let flops = 2 * a.rows() * a.cols() * out.cols();
    let t = effective_threads(flops, out.cols(), GemmMode::Fast);
    blocked_fan_out(View::of(a).transposed(), b32.view(), out, false, t);
}

/// Forced-blocked f64 `out = a · b`. Mixed-mode kernels use this for their
/// exact-f64 sub-products so mixed arithmetic never depends on the
/// `gram.gemm` knob — serial, sharded and remote mixed paths all run the
/// identical blocked reduction regardless of how the mode knob is set.
pub fn blocked_matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    let flops = Kind::Mul.check(a, b, out);
    let t = effective_threads(flops, out.cols(), GemmMode::Fast);
    blocked_fan_out(View::of(a), View::of(b), out, false, t);
}

/// `out = a * b`, parallel over output columns (auto thread count).
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    let mode = gemm::mode();
    let flops = Kind::Mul.check(a, b, out);
    product(Kind::Mul, false, a, b, out, effective_threads(flops, out.cols(), mode), mode);
}

/// `out = a * b` with an explicit worker count.
pub fn matmul_into_with(a: &Mat, b: &Mat, out: &mut Mat, nthreads: usize) {
    Kind::Mul.check(a, b, out);
    product(Kind::Mul, false, a, b, out, nthreads, gemm::mode());
}

/// `out += a * b`, parallel over output columns (auto thread count).
pub fn matmul_acc(a: &Mat, b: &Mat, out: &mut Mat) {
    let mode = gemm::mode();
    let flops = Kind::Mul.check(a, b, out);
    product(Kind::Mul, true, a, b, out, effective_threads(flops, out.cols(), mode), mode);
}

/// `out += a * b` with an explicit worker count.
pub fn matmul_acc_with(a: &Mat, b: &Mat, out: &mut Mat, nthreads: usize) {
    Kind::Mul.check(a, b, out);
    product(Kind::Mul, true, a, b, out, nthreads, gemm::mode());
}

/// `a * b` allocating, parallel over output columns.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut out);
    out
}

/// `out = aᵀ * b`, parallel over output columns (auto thread count).
pub fn t_matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    let mode = gemm::mode();
    let flops = Kind::TMul.check(a, b, out);
    product(Kind::TMul, false, a, b, out, effective_threads(flops, out.cols(), mode), mode);
}

/// `out = aᵀ * b` with an explicit worker count.
pub fn t_matmul_into_with(a: &Mat, b: &Mat, out: &mut Mat, nthreads: usize) {
    Kind::TMul.check(a, b, out);
    product(Kind::TMul, false, a, b, out, nthreads, gemm::mode());
}

/// `aᵀ * b` allocating, parallel over output columns.
pub fn t_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.cols(), b.cols());
    t_matmul_into(a, b, &mut out);
    out
}

/// `out = a * bᵀ`, parallel over output columns (auto thread count).
pub fn matmul_t_into(a: &Mat, b: &Mat, out: &mut Mat) {
    let mode = gemm::mode();
    let flops = Kind::MulT.check(a, b, out);
    product(Kind::MulT, false, a, b, out, effective_threads(flops, out.cols(), mode), mode);
}

/// `out = a * bᵀ` with an explicit worker count.
pub fn matmul_t_into_with(a: &Mat, b: &Mat, out: &mut Mat, nthreads: usize) {
    Kind::MulT.check(a, b, out);
    product(Kind::MulT, false, a, b, out, nthreads, gemm::mode());
}

/// `a * bᵀ` allocating, parallel over output columns.
pub fn matmul_t(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows(), b.rows());
    matmul_t_into(a, b, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sample(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.gauss())
    }

    #[test]
    fn knob_clamps_and_persists() {
        let before = threads();
        set_threads(0);
        assert_eq!(threads(), 1);
        set_threads(4);
        assert_eq!(threads(), 4);
        set_threads(before);
    }

    #[test]
    fn forced_parallel_matches_serial_small() {
        // exact mode pinned explicitly through the driver: the public
        // wrappers dispatch on the global knob, and this pin is about the
        // exact path specifically (fast has its own partition-invariance
        // pins below and in tests/gemm_path.rs).
        let a = sample(7, 5, 1);
        let b = sample(5, 9, 2);
        let want = a.matmul(&b);
        let mut got = Mat::zeros(7, 9);
        product(Kind::Mul, false, &a, &b, &mut got, 4, GemmMode::Exact);
        assert!((&got - &want).max_abs() == 0.0, "parallel path must be bit-identical");
    }

    #[test]
    fn fast_path_is_thread_count_invariant() {
        // the fast-mode analogue of the pin above: any thread count must
        // reproduce the single-thread blocked result bit-for-bit.
        let a = sample(23, 37, 5);
        let b = sample(37, 29, 6);
        let bt = sample(29, 37, 7);
        let mut one = Mat::zeros(23, 29);
        product(Kind::Mul, false, &a, &b, &mut one, 1, GemmMode::Fast);
        for t in [2, 3, 5, 8] {
            let mut got = Mat::zeros(23, 29);
            product(Kind::Mul, false, &a, &b, &mut got, t, GemmMode::Fast);
            assert!(got == one, "fast Mul threads={t}");
        }
        let at = sample(23, 14, 8);
        let b2 = sample(23, 29, 9);
        let mut one = Mat::zeros(14, 29);
        product(Kind::TMul, false, &at, &b2, &mut one, 1, GemmMode::Fast);
        for t in [2, 4, 7] {
            let mut got = Mat::zeros(14, 29);
            product(Kind::TMul, false, &at, &b2, &mut got, t, GemmMode::Fast);
            assert!(got == one, "fast TMul threads={t}");
        }
        let mut one = Mat::zeros(23, 29);
        product(Kind::MulT, false, &a, &bt, &mut one, 1, GemmMode::Fast);
        for t in [2, 4, 7] {
            let mut got = Mat::zeros(23, 29);
            product(Kind::MulT, false, &a, &bt, &mut got, t, GemmMode::Fast);
            assert!(got == one, "fast MulT threads={t}");
        }
    }

    #[test]
    fn fast_acc_accumulates_onto_seed() {
        let a = sample(9, 65, 10);
        let b = sample(65, 6, 11);
        let seed = sample(9, 6, 12);
        let mut got = seed.clone();
        product(Kind::Mul, true, &a, &b, &mut got, 3, GemmMode::Fast);
        let mut prod = Mat::zeros(9, 6);
        product(Kind::Mul, false, &a, &b, &mut prod, 1, GemmMode::Fast);
        // k = 65 < KC = 256, so the product is a single depth block and the
        // accumulate path adds exactly one partial onto the seed: acc must
        // equal seed + prod bitwise.
        let want = &seed + &prod;
        assert!((&got - &want).max_abs() == 0.0);
    }

    #[test]
    fn mixed_fan_out_is_thread_count_invariant_and_matches_widened_reference() {
        let a = sample(23, 300, 81);
        let b = sample(300, 29, 83);
        let a32 = MatF32::round_from(&a);
        // single-thread blocked result over the widened tier is the anchor
        let mut one = Mat::zeros(23, 29);
        blocked_fan_out(a32.view(), View::of(&b), &mut one, false, 1);
        for t in [2, 3, 5, 8] {
            let mut got = Mat::zeros(23, 29);
            blocked_fan_out(a32.view(), View::of(&b), &mut got, false, t);
            assert!(got == one, "mixed fan-out threads={t} must be bit-identical");
        }
        // and the anchor equals the forced-blocked f64 product of the
        // widened tier bitwise (widening at pack == widening up front)
        let wide = a32.widen();
        let mut ref_blocked = Mat::zeros(23, 29);
        blocked_matmul_into(&wide, &b, &mut ref_blocked);
        assert!(one == ref_blocked);
    }

    #[test]
    fn mixed_t_matmul_matches_widened_reference_bitwise() {
        let a = sample(40, 13, 87);
        let b = sample(40, 17, 89);
        let b32 = MatF32::round_from(&b);
        let mut got = Mat::zeros(13, 17);
        mixed_t_matmul_into(&a, &b32, &mut got);
        let wide = b32.widen();
        let mut want = Mat::zeros(13, 17);
        blocked_fan_out(View::of(&a).transposed(), View::of(&wide), &mut want, false, 1);
        assert!(got == want);
    }

    #[test]
    fn mixed_matmul_accumulates_onto_seed() {
        let a = sample(9, 65, 91);
        let b = sample(65, 6, 93);
        let a32 = MatF32::round_from(&a);
        let seed = sample(9, 6, 95);
        let mut got = seed.clone();
        mixed_matmul_into(&a32, &b, &mut got, true);
        let mut prod = Mat::zeros(9, 6);
        mixed_matmul_into(&a32, &b, &mut prod, false);
        // k = 65 < KC: single depth block, so acc == seed + prod bitwise
        let want = &seed + &prod;
        assert!((&got - &want).max_abs() == 0.0);
    }

    #[test]
    fn par_columns_covers_every_column_once() {
        let mut out = Mat::zeros(3, 10);
        par_columns(&mut out, 4, |j, col| {
            for v in col.iter_mut() {
                *v += (j + 1) as f64;
            }
        });
        for j in 0..10 {
            for i in 0..3 {
                assert_eq!(out[(i, j)], (j + 1) as f64, "col {j}");
            }
        }
    }

    #[test]
    fn zero_sized_outputs_are_noops() {
        let a = sample(4, 3, 3);
        let b = Mat::zeros(3, 0);
        let mut out = Mat::zeros(4, 0);
        matmul_into_with(&a, &b, &mut out, 4);
        let a0 = Mat::zeros(0, 3);
        let mut out0 = Mat::zeros(0, 5);
        matmul_into_with(&a0, &sample(3, 5, 4), &mut out0, 4);
        // both modes must survive the degenerate shapes
        for mode in [GemmMode::Exact, GemmMode::Fast] {
            let mut out = Mat::zeros(4, 0);
            product(Kind::Mul, false, &a, &b, &mut out, 4, mode);
            let mut out0 = Mat::zeros(0, 5);
            product(Kind::Mul, false, &a0, &sample(3, 5, 4), &mut out0, 4, mode);
        }
    }
}
