//! PJRT runtime: load and execute the AOT-compiled HLO-text artifacts.
//!
//! The compile path (`make artifacts`) runs python/JAX **once** and writes
//! `artifacts/*.hlo.txt` plus `manifest.toml`; this module is the only thing
//! that touches them at run time:
//!
//! ```text
//! manifest.toml ─▶ ArtifactRegistry ─▶ PjRtClient::cpu()
//!                      │                    │
//!                      └── HloModuleProto::from_text_file ─▶ compile ─▶ execute
//! ```
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while the
//! text parser reassigns ids — see `/opt/xla-example/README.md`.
//!
//! Conventions: all artifact tensors are `f32`, row-major in the python
//! `(D, N)` layout. [`Mat`] is column-major `f64`, so the boundary helpers
//! transpose + cast in both directions.
//!
//! Build gating: the `xla` crate is not in the offline registry, so all PJRT
//! execution is behind the `pjrt` cargo feature. Without it the registry
//! still opens and lists manifests (pure rust), but `execute_*` returns a
//! descriptive error. Consumers must therefore not treat a successful
//! `open()` as "execution available": gate engine selection on
//! `cfg!(feature = "pjrt")` (as `examples/serve_gradients.rs` does) or
//! handle the execute error (as the benches do). `gdkron validate`
//! intentionally fails loudly in a non-pjrt build — it exists to prove the
//! artifacts execute.

#[cfg(feature = "pjrt")]
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::config::Config;
use crate::linalg::Mat;

/// Shape+dtype of one artifact input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimensions; empty = scalar.
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Parse the manifest encoding `"f32:8x4"` / `"f32:scalar"`.
    fn parse(s: &str) -> anyhow::Result<Self> {
        let rest = s
            .strip_prefix("f32:")
            .ok_or_else(|| anyhow::anyhow!("unsupported dtype in spec {s:?} (only f32)"))?;
        if rest == "scalar" {
            return Ok(TensorSpec { dims: vec![] });
        }
        let dims = rest
            .split('x')
            .map(|p| p.parse::<usize>().map_err(|e| anyhow::anyhow!("bad dim {p:?}: {e}")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(TensorSpec { dims })
    }
}

/// One entry of `manifest.toml`.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub description: String,
    pub inputs: Vec<TensorSpec>,
}

/// Argument value for [`ArtifactRegistry::execute_raw`].
pub enum ArgValue<'a> {
    /// A `D×N` matrix (transposed+cast to the python row-major f32 layout).
    Mat(&'a Mat),
    /// A scalar parameter (e.g. `inv_l2`).
    Scalar(f64),
}

/// Loads artifacts per the manifest and executes them on the PJRT CPU
/// client. Executables are compiled lazily on first use and cached.
pub struct ArtifactRegistry {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    specs: HashMap<String, ArtifactSpec>,
    #[cfg(feature = "pjrt")]
    compiled: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl ArtifactRegistry {
    /// Open the artifact directory (must contain `manifest.toml`).
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref();
        let manifest = Config::from_file(dir.join("manifest.toml"))?;
        let mut specs = HashMap::new();
        for name in manifest.subsections("artifact") {
            let key = |k: &str| format!("artifact.{name}.{k}");
            let file = manifest
                .str(&key("file"))
                .ok_or_else(|| anyhow::anyhow!("artifact {name}: missing file"))?;
            let inputs = manifest
                .str_array(&key("inputs"))
                .ok_or_else(|| anyhow::anyhow!("artifact {name}: missing inputs"))?
                .iter()
                .map(|s| TensorSpec::parse(s))
                .collect::<anyhow::Result<Vec<_>>>()?;
            specs.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(file),
                    description: manifest.str(&key("description")).unwrap_or("").to_string(),
                    inputs,
                },
            );
        }
        anyhow::ensure!(!specs.is_empty(), "no artifacts found in {dir:?}");
        Self::finish(specs)
    }

    /// Attach the PJRT client to the parsed manifest.
    #[cfg(feature = "pjrt")]
    fn finish(specs: HashMap<String, ArtifactSpec>) -> anyhow::Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu: {e:?}"))?;
        Ok(ArtifactRegistry { client, specs, compiled: RefCell::new(HashMap::new()) })
    }

    /// Without the `pjrt` feature the registry is manifest-only.
    #[cfg(not(feature = "pjrt"))]
    fn finish(specs: HashMap<String, ArtifactSpec>) -> anyhow::Result<Self> {
        Ok(ArtifactRegistry { specs })
    }

    /// Artifact names available.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.specs.keys().cloned().collect();
        v.sort();
        v
    }

    /// Spec lookup.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// Compile (or fetch the cached) executable.
    #[cfg(feature = "pjrt")]
    fn ensure_compiled(&self, name: &str) -> anyhow::Result<()> {
        if self.compiled.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .map_err(|e| anyhow::anyhow!("parsing {:?}: {e:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        self.compiled.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact. Returns the first (and only) tuple element as a
    /// flat row-major `f32` buffer converted to `f64`.
    #[cfg(feature = "pjrt")]
    pub fn execute_raw(&self, name: &str, args: &[ArgValue]) -> anyhow::Result<Vec<f64>> {
        self.ensure_compiled(name)?;
        let spec = &self.specs[name];
        anyhow::ensure!(
            args.len() == spec.inputs.len(),
            "artifact {name}: expected {} args, got {}",
            spec.inputs.len(),
            args.len()
        );
        let mut literals = Vec::with_capacity(args.len());
        for (arg, ts) in args.iter().zip(&spec.inputs) {
            literals.push(to_literal(arg, ts)?);
        }
        let compiled = self.compiled.borrow();
        let exe = &compiled[name];
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untupling {name} result: {e:?}"))?;
        let v = out
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("reading {name} result: {e:?}"))?;
        Ok(v.into_iter().map(|x| x as f64).collect())
    }

    /// Stub without the `pjrt` feature: the manifest is known but there is no
    /// execution backend; consumers fall back to the native engine.
    #[cfg(not(feature = "pjrt"))]
    pub fn execute_raw(&self, name: &str, _args: &[ArgValue]) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(self.specs.contains_key(name), "unknown artifact {name:?}");
        anyhow::bail!(
            "artifact {name:?}: PJRT backend not built — rebuild with `--features pjrt` \
             and a vendored `xla` crate (see runtime module docs)"
        )
    }

    /// Execute an artifact whose output is a `(D, N)` python-layout tensor,
    /// returned as a column-major [`Mat`].
    pub fn execute_mat(
        &self,
        name: &str,
        args: &[ArgValue],
        d: usize,
        n: usize,
    ) -> anyhow::Result<Mat> {
        let flat = self.execute_raw(name, args)?;
        anyhow::ensure!(flat.len() == d * n, "output size {} != {d}x{n}", flat.len());
        // row-major (D, N) → col-major D×N
        Ok(Mat::from_fn(d, n, |i, j| flat[i * n + j]))
    }
}

/// Convert an argument to an XLA literal in the artifact layout.
#[cfg(feature = "pjrt")]
fn to_literal(arg: &ArgValue, spec: &TensorSpec) -> anyhow::Result<xla::Literal> {
    match arg {
        ArgValue::Scalar(v) => {
            anyhow::ensure!(spec.dims.is_empty(), "scalar passed for tensor input");
            Ok(xla::Literal::scalar(*v as f32))
        }
        ArgValue::Mat(m) => {
            anyhow::ensure!(
                spec.dims.len() == 2 && spec.dims[0] == m.rows() && spec.dims[1] == m.cols(),
                "matrix {}x{} does not match artifact input {:?}",
                m.rows(),
                m.cols(),
                spec.dims
            );
            // col-major D×N f64 → row-major (D, N) f32
            let (d, n) = (m.rows(), m.cols());
            let mut buf = vec![0f32; d * n];
            for j in 0..n {
                let col = m.col(j);
                for i in 0..d {
                    buf[i * n + j] = col[i] as f32;
                }
            }
            xla::Literal::vec1(&buf)
                .reshape(&[d as i64, n as i64])
                .map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_parses() {
        assert_eq!(TensorSpec::parse("f32:8x4").unwrap().dims, vec![8, 4]);
        assert!(TensorSpec::parse("f32:scalar").unwrap().dims.is_empty());
        assert!(TensorSpec::parse("f64:8x4").is_err());
        assert!(TensorSpec::parse("f32:8xq").is_err());
    }
}
