//! Block-CG oracle tests on the structured Gram operators.
//!
//! Mirrors the `gram_oracle.rs` harness: build `GramFactors` for a kernel,
//! materialize the dense `ND×ND` Gram as the ground-truth oracle, and check
//! the matrix-free solvers against it. On top of correctness, the
//! `block_cg_beats_sequential_cg_on_serving_batch` test pins the PR's
//! throughput claim: solving `K = 8` right-hand sides on a `D=256, N=8` SE
//! Gram operator with one block-CG run costs **fewer total operator
//! applications** than eight sequential `cg_solve` runs.
//!
//! All operators here are built through `GramOperator::new_exact`: these
//! are solver-plumbing oracles pinned at f64 tolerances, so they must stay
//! inert under the `GDKRON_PRECISION=mixed` CI leg (where `new` would
//! dispatch the ~ε_f32 tier kernels). The mixed operator's own solve
//! accuracy is pinned by `benches/precision_tier.rs` and
//! `tests/model_parity.rs`.

use gdkron::gram::{GramFactors, GramOperator, Metric};
use gdkron::kernels::{Matern52, ScalarKernel, SquaredExponential};
use gdkron::linalg::{par, Lu, Mat};
use gdkron::rng::Rng;
use gdkron::solvers::{block_cg_solve, cg_solve, CgOptions, JacobiPrecond, LinearOp};

fn sample_x(d: usize, n: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(d, n, |_, _| rng.uniform_in(-2.0, 2.0))
}

/// Build a noised Gram operator (SPD) the way the serving path does.
fn factors(kern: &dyn ScalarKernel, d: usize, n: usize, seed: u64) -> GramFactors {
    let x = sample_x(d, n, seed);
    let inv_l2 = 1.0 / (10.0 * d as f64);
    GramFactors::with_noise(kern, &x, Metric::Iso(inv_l2), None, 1e-4)
}

fn gauss_block(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.gauss())
}

/// Oracle check: block-CG on a stacked RHS matrix matches (a) `cg_solve`
/// column-by-column and (b) the dense LU solve, on the given kernel.
fn check_block_matches_columnwise(kern: &dyn ScalarKernel, seed: u64) {
    let (d, n, k) = (12, 5, 4);
    let f = factors(kern, d, n, seed);
    let op = GramOperator::new_exact(&f);
    let b = gauss_block(d * n, k, seed + 100);
    let opts = CgOptions {
        rtol: 1e-11,
        max_iters: 5000,
        precond: Some(JacobiPrecond::new(&f.gram_diag())),
        track_history: false,
    };
    let block = block_cg_solve(&op, &b, &opts);
    assert!(block.all_converged(), "{}: rel {:?}", kern.name(), block.rel_residuals);

    // (a) column-by-column single-RHS CG
    for j in 0..k {
        let single = cg_solve(&op, b.col(j), None, &opts);
        assert!(single.converged, "{} col {j}", kern.name());
        let err: f64 = block
            .x
            .col(j)
            .iter()
            .zip(&single.x)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0, f64::max);
        let scale: f64 = single.x.iter().fold(1.0_f64, |m, v| m.max(v.abs()));
        assert!(err < 1e-6 * scale, "{} col {j}: block vs cg err {err}", kern.name());
    }

    // (b) dense oracle
    let dense = f.to_dense();
    let lu = Lu::factor(&dense).unwrap();
    for j in 0..k {
        let want = lu.solve_vec(b.col(j));
        let err: f64 = block
            .x
            .col(j)
            .iter()
            .zip(&want)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0, f64::max);
        let scale: f64 = want.iter().fold(1.0_f64, |m, v| m.max(v.abs()));
        assert!(err < 1e-5 * scale, "{} col {j}: block vs dense err {err}", kern.name());
    }
}

#[test]
fn block_cg_matches_columnwise_cg_on_se_gram() {
    check_block_matches_columnwise(&SquaredExponential, 1);
}

#[test]
fn block_cg_matches_columnwise_cg_on_matern52_gram() {
    check_block_matches_columnwise(&Matern52, 2);
}

#[test]
fn iteration_cap_exercises_per_column_convergence_flags() {
    let f = factors(&SquaredExponential, 10, 4, 3);
    let op = GramOperator::new_exact(&f);
    let b = gauss_block(40, 3, 33);
    // unreachable tolerance + tiny cap: nothing converges, every column
    // must report its own (false) flag and a finite residual.
    let capped = block_cg_solve(
        &op,
        &b,
        &CgOptions { rtol: 1e-15, max_iters: 2, precond: None, track_history: true },
    );
    assert_eq!(capped.iters, 2);
    assert_eq!(capped.converged, vec![false, false, false]);
    assert!(capped.rel_residuals.iter().all(|r| r.is_finite() && *r > 1e-15));
    assert_eq!(capped.resid_history.len(), capped.iters + 1);
    // the same system converges column-by-column once the cap is lifted
    let free = block_cg_solve(
        &op,
        &b,
        &CgOptions {
            rtol: 1e-9,
            max_iters: 5000,
            precond: Some(JacobiPrecond::new(&f.gram_diag())),
            track_history: false,
        },
    );
    assert!(free.all_converged());
}

/// The PR's acceptance pin: K=8 RHS on the D=256, N=8 SE Gram operator —
/// one block-CG run performs fewer total (column-equivalent) operator
/// applications than 8 sequential CG solves, at matching accuracy; and the
/// parallel and serial linalg paths agree on the operator itself to ≤1e-12.
#[test]
fn block_cg_beats_sequential_cg_on_serving_batch() {
    let (d, n, k) = (256, 8, 8);
    let f = factors(&SquaredExponential, d, n, 4);
    let op = GramOperator::new_exact(&f);
    let b = gauss_block(d * n, k, 44);
    let opts = CgOptions {
        rtol: 1e-6,
        max_iters: 5000,
        precond: Some(JacobiPrecond::new(&f.gram_diag())),
        track_history: false,
    };

    // sequential baseline: one CG run per column, each costing
    // `iters + 1` operator applications (the +1 is the initial residual).
    let mut seq_applies = 0;
    let mut seq_x = Mat::zeros(d * n, k);
    for j in 0..k {
        let res = cg_solve(&op, b.col(j), None, &opts);
        assert!(res.converged, "sequential col {j} did not converge");
        seq_applies += res.iters + 1;
        seq_x.set_col(j, &res.x);
    }

    let block = block_cg_solve(&op, &b, &opts);
    assert!(block.all_converged(), "rel {:?}", block.rel_residuals);
    assert_eq!(block.fallback_cols, 0, "random RHS must not trip the fallback");
    assert!(
        block.col_applies < seq_applies,
        "block CG must beat sequential: {} vs {} column applications",
        block.col_applies,
        seq_applies
    );

    // both solvers agree with each other (same operator, same tolerance)
    let scale = 1.0 + seq_x.max_abs();
    assert!(
        (&block.x - &seq_x).max_abs() < 1e-4 * scale,
        "block and sequential solutions diverged"
    );

    // parallel vs serial operator application agree to ≤ 1e-12: toggle the
    // global pool inside this one test (other tests don't pin the knob).
    let before = par::threads();
    let probe = gauss_block(d * n, 1, 45);
    par::set_threads(1);
    let mut serial = vec![0.0; d * n];
    op.apply(probe.col(0), &mut serial);
    par::set_threads(4);
    let mut parallel = vec![0.0; d * n];
    op.apply(probe.col(0), &mut parallel);
    par::set_threads(before);
    let err: f64 = serial
        .iter()
        .zip(&parallel)
        .map(|(p, q)| (p - q).abs())
        .fold(0.0, f64::max);
    assert!(err <= 1e-12, "parallel vs serial Gram matvec differ by {err}");
}
