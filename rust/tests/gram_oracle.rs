//! Integration oracle: verifies the `∇K∇′ = B + UCUᵀ` factorization against
//! explicitly materialized `U` and `C` matrices (App. B.2 / B.3), i.e. the
//! exact object pictured in the paper's Fig. 1 — and that the Woodbury core
//! assembled by the solver equals the dense `C⁻¹ + UᵀB⁻¹U`.

use gdkron::gram::{GramFactors, Metric};
use gdkron::kernels::{ExponentialKernel, KernelClass, ScalarKernel, SquaredExponential};
use gdkron::linalg::{Lu, Mat};
use gdkron::rng::Rng;

/// Dense U with pair columns F(a,p) = a·N + p.
/// dot product:  column (a,p) = e_a ⊗ Λx̃_p
/// stationary:   column (a,p) = e_a ⊗ Λ(x_a − x_p)
fn dense_u(f: &GramFactors, n: usize, d: usize) -> Mat {
    let mut u = Mat::zeros(n * d, n * n);
    for a in 0..n {
        for p in 0..n {
            for i in 0..d {
                let v = match f.class {
                    KernelClass::DotProduct => f.lam_xt[(i, p)],
                    KernelClass::Stationary => f.lam_xt[(i, a)] - f.lam_xt[(i, p)],
                };
                u[(a * d + i, a * n + p)] = v;
            }
        }
    }
    u
}

/// Dense C: C[(a,p),(b,p′)] = σ K̂″_ab δ_pb δ_p′a with σ = +1 (dot), −1 (stationary).
fn dense_c(f: &GramFactors, n: usize) -> Mat {
    let sign = match f.class {
        KernelClass::DotProduct => 1.0,
        KernelClass::Stationary => -1.0,
    };
    let mut c = Mat::zeros(n * n, n * n);
    for a in 0..n {
        for b in 0..n {
            c[(a * n + b, b * n + a)] = sign * f.kpp_eff[(a, b)];
        }
    }
    c
}

fn check_factorization(kern: &dyn ScalarKernel, metric: Metric, center: Option<&[f64]>, seed: u64) {
    let (d, n) = (5, 3);
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(d, n, |_, _| rng.gauss());
    let f = GramFactors::new(kern, &x, metric, center);
    let dense = f.to_dense();
    let u = dense_u(&f, n, d);
    let c = dense_c(&f, n);
    let b = f.kp_eff.kron(&f.metric.to_dense(d));
    let rec = &b + &u.matmul(&c).matmul_t(&u);
    let err = (&rec - &dense).max_abs();
    assert!(
        err < 1e-12 * (1.0 + dense.max_abs()),
        "{}: B + UCUᵀ reconstruction error {err}",
        kern.name()
    );
}

#[test]
fn dot_product_factorization_reconstructs_gram() {
    let c = [0.3, -0.2, 0.5, 0.1, -0.4];
    check_factorization(&ExponentialKernel, Metric::Iso(0.15), Some(&c), 6);
    check_factorization(&ExponentialKernel, Metric::Diag(vec![0.3, 0.7, 1.1, 0.5, 0.9]), None, 7);
}

#[test]
fn stationary_factorization_reconstructs_gram() {
    check_factorization(&SquaredExponential, Metric::Iso(0.8), None, 8);
    check_factorization(
        &SquaredExponential,
        Metric::Diag(vec![0.4, 1.2, 0.6, 0.9, 1.5]),
        None,
        9,
    );
}

#[test]
fn woodbury_identity_det_consistency() {
    // det(B + UCUᵀ) = det(B)·det(C)·det(C⁻¹ + UᵀB⁻¹U): the core is singular
    // iff the Gram is (given B, C invertible) — the invariant behind the
    // solver's error reporting.
    let (d, n) = (4, 3);
    let mut rng = Rng::new(10);
    let x = Mat::from_fn(d, n, |_, _| rng.gauss());
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(0.6), None);
    let dense = f.to_dense();
    let u = dense_u(&f, n, d);
    let c = dense_c(&f, n);
    let b = f.kp_eff.kron(&f.metric.to_dense(d));
    let det_gram = Lu::factor(&dense).unwrap().det();
    let det_b = Lu::factor(&b).unwrap().det();
    let c_lu = Lu::factor(&c).unwrap();
    let det_c = c_lu.det();
    let core = &c_lu.inverse() + &u.t_matmul(&Lu::factor(&b).unwrap().inverse().matmul(&u));
    let det_core = Lu::factor(&core).unwrap().det();
    let lhs = det_gram;
    let rhs = det_b * det_c * det_core;
    assert!(
        (lhs - rhs).abs() < 1e-8 * lhs.abs().max(rhs.abs()),
        "det identity violated: {lhs} vs {rhs}"
    );
}
