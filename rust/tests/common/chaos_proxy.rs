//! A deterministic, frame-aware TCP chaos proxy for fault-injection tests.
//!
//! Sits between a coordinator and a `gdkron shard-worker`, forwarding the
//! length-prefixed wire frames (`[len:u32][tag:u8][payload]`) while a
//! scripted fault plan injects failures at exact points:
//!
//! * **sever** — close both directions (also kills live connections and
//!   refuses new ones until [`ChaosProxy::restore`]): the network
//!   partition / worker-kill fault;
//! * **truncate** — forward a frame header that promises more payload than
//!   is sent, then close: the mid-frame corruption;
//! * **corrupt** — flip a bit at a chosen byte of a forwarded frame
//!   (byte 4 is the tag, so `Corrupt { byte: 4 }` turns a valid frame into
//!   an unknown-tag protocol error);
//! * **delay** — stall a frame longer than the coordinator's read timeout.
//!
//! Faults are scripted as "after N frames in direction D" and consumed
//! exactly once, so every test run injects at the same protocol point —
//! no timing races. The upstream address is swappable
//! ([`ChaosProxy::set_upstream`]), which is how tests model a worker that
//! dies and is *restarted elsewhere* while keeping the registered address
//! (the proxy's) stable — exactly the shard-registry model.
//!
//! Reusable support code: include with
//! `#[path = "common/chaos_proxy.rs"] mod chaos_proxy;` from any
//! integration test.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

/// Which pump a fault applies to.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Direction {
    /// Coordinator → worker frames.
    ToWorker,
    /// Worker → coordinator frames.
    ToCoordinator,
}

/// What happens when the scripted point is reached.
#[derive(Clone, Debug)]
pub enum FaultKind {
    /// Close both directions of the current connection.
    Sever,
    /// Forward the frame header plus only `keep` payload bytes, then close.
    Truncate { keep: usize },
    /// Flip bit 6 of the frame byte at `byte` (0..4 = length prefix, 4 =
    /// tag, 5.. = payload), forward the damaged frame, keep pumping.
    Corrupt { byte: usize },
    /// Sleep before forwarding the frame (stalls everything behind it).
    Delay(Duration),
}

/// One scripted fault: fires on the first frame in `dir` whose index
/// (0-based count of frames already forwarded in that direction on the
/// current connection) is ≥ `after_frames`. Consumed exactly once.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub dir: Direction,
    pub after_frames: usize,
    pub kind: FaultKind,
}

struct Ctl {
    upstream: Mutex<String>,
    severed: AtomicBool,
    /// Bumped by sever(): live pumps compare and shut down.
    conn_epoch: AtomicU64,
    plan: Mutex<Option<FaultPlan>>,
}

/// Handle to one running proxy (the accept loop runs until the test
/// process exits).
pub struct ChaosProxy {
    addr: String,
    ctl: Arc<Ctl>,
}

enum PumpRead {
    Ok,
    Closed,
}

/// Read exactly `buf.len()` bytes with a short poll timeout so the pump
/// notices sever/epoch changes promptly.
fn read_full(src: &mut TcpStream, buf: &mut [u8], ctl: &Ctl, epoch: u64) -> PumpRead {
    let mut got = 0;
    while got < buf.len() {
        if ctl.severed.load(Ordering::SeqCst) || ctl.conn_epoch.load(Ordering::SeqCst) != epoch {
            return PumpRead::Closed;
        }
        match src.read(&mut buf[got..]) {
            Ok(0) => return PumpRead::Closed,
            Ok(k) => got += k,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue
            }
            Err(_) => return PumpRead::Closed,
        }
    }
    PumpRead::Ok
}

fn close_both(a: &TcpStream, b: &TcpStream) {
    let _ = a.shutdown(Shutdown::Both);
    let _ = b.shutdown(Shutdown::Both);
}

/// One direction of a proxied connection, frame by frame.
fn pump(mut src: TcpStream, mut dst: TcpStream, dir: Direction, ctl: Arc<Ctl>, epoch: u64) {
    let _ = src.set_read_timeout(Some(Duration::from_millis(25)));
    let mut forwarded = 0usize;
    loop {
        let mut hdr = [0u8; 5];
        match read_full(&mut src, &mut hdr, &ctl, epoch) {
            PumpRead::Ok => {}
            PumpRead::Closed => {
                close_both(&src, &dst);
                return;
            }
        }
        let len = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]) as usize;
        let mut payload = vec![0u8; len];
        match read_full(&mut src, &mut payload, &ctl, epoch) {
            PumpRead::Ok => {}
            PumpRead::Closed => {
                close_both(&src, &dst);
                return;
            }
        }
        // consume the scripted fault if this frame is its trigger point
        let fault = {
            let mut guard = ctl.plan.lock().unwrap();
            let due = matches!(&*guard, Some(p) if p.dir == dir && forwarded >= p.after_frames);
            if due {
                guard.take()
            } else {
                None
            }
        };
        let mut frame = Vec::with_capacity(5 + len);
        frame.extend_from_slice(&hdr);
        frame.extend_from_slice(&payload);
        match fault.map(|p| p.kind) {
            Some(FaultKind::Sever) => {
                close_both(&src, &dst);
                return;
            }
            Some(FaultKind::Truncate { keep }) => {
                frame.truncate(5 + keep.min(len));
                let _ = dst.write_all(&frame);
                let _ = dst.flush();
                close_both(&src, &dst);
                return;
            }
            Some(FaultKind::Corrupt { byte }) => {
                if !frame.is_empty() {
                    let i = byte.min(frame.len() - 1);
                    frame[i] ^= 0x40;
                }
            }
            Some(FaultKind::Delay(d)) => {
                thread::sleep(d);
            }
            None => {}
        }
        if dst.write_all(&frame).and_then(|_| dst.flush()).is_err() {
            close_both(&src, &dst);
            return;
        }
        forwarded += 1;
    }
}

impl ChaosProxy {
    /// Bind a loopback port and start proxying to `upstream`.
    pub fn spawn(upstream: String) -> ChaosProxy {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind chaos proxy");
        let addr = listener.local_addr().unwrap().to_string();
        let ctl = Arc::new(Ctl {
            upstream: Mutex::new(upstream),
            severed: AtomicBool::new(false),
            conn_epoch: AtomicU64::new(0),
            plan: Mutex::new(None),
        });
        let accept_ctl = Arc::clone(&ctl);
        thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(client) = conn else { return };
                if accept_ctl.severed.load(Ordering::SeqCst) {
                    // partitioned: the client sees an immediate EOF
                    let _ = client.shutdown(Shutdown::Both);
                    continue;
                }
                let upstream_addr = accept_ctl.upstream.lock().unwrap().clone();
                let Ok(server) = TcpStream::connect(&upstream_addr) else {
                    let _ = client.shutdown(Shutdown::Both);
                    continue;
                };
                let _ = client.set_nodelay(true);
                let _ = server.set_nodelay(true);
                let epoch = accept_ctl.conn_epoch.load(Ordering::SeqCst);
                let (c2, s2) = match (client.try_clone(), server.try_clone()) {
                    (Ok(c), Ok(s)) => (c, s),
                    _ => {
                        let _ = client.shutdown(Shutdown::Both);
                        continue;
                    }
                };
                let up_ctl = Arc::clone(&accept_ctl);
                let down_ctl = Arc::clone(&accept_ctl);
                thread::spawn(move || pump(client, server, Direction::ToWorker, up_ctl, epoch));
                thread::spawn(move || pump(s2, c2, Direction::ToCoordinator, down_ctl, epoch));
            }
        });
        ChaosProxy { addr, ctl }
    }

    /// The address coordinators (and the registry) should dial.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Partition: kill live connections and refuse new ones until
    /// [`ChaosProxy::restore`].
    pub fn sever(&self) {
        self.ctl.conn_epoch.fetch_add(1, Ordering::SeqCst);
        self.ctl.severed.store(true, Ordering::SeqCst);
    }

    /// Heal the partition: new connections flow again (to the current
    /// upstream).
    pub fn restore(&self) {
        self.ctl.severed.store(false, Ordering::SeqCst);
    }

    /// Re-point the proxy at a different upstream worker — the
    /// "worker restarted elsewhere, registered address unchanged" model.
    pub fn set_upstream(&self, addr: &str) {
        *self.ctl.upstream.lock().unwrap() = addr.to_string();
    }

    /// Install the next scripted fault (consumed once when it fires).
    pub fn script_fault(&self, plan: FaultPlan) {
        *self.ctl.plan.lock().unwrap() = Some(plan);
    }
}
