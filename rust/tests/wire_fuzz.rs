//! Deterministic property/fuzz corpus over the `gram::wire` codec.
//!
//! The wire layer is the trust boundary of the cross-node shard transport:
//! whatever arrives on the socket — truncated, inflated, tag-mutated,
//! bit-flipped — decode must **never panic, never over-allocate, and
//! always return a descriptive error** for malformed input. This suite
//! pins that with the in-tree deterministic [`Rng`] (fixed seeds, so every
//! CI run fuzzes the same corpus):
//!
//! * round-trip property for **every** frame type, including the v2
//!   health/registry frames (`Ping`/`Pong`/`SyncAt`), the v3
//!   epoch-fence frames (`Claim`/`ClaimAck`) and the v4 mixed-tier
//!   frames (`SyncAtF32`/`AppendF32` — `round ∘ widen = id` makes even
//!   the narrowed panels re-encode exactly): encode → frame-read →
//!   decode → re-encode is byte-identical;
//! * every truncation of every valid encoding is a clean error;
//! * length-field inflation (header promising more payload than sent, up
//!   to `u32::MAX`) is a clean error — the `MAX_FRAME_BYTES` cap rejects
//!   hostile lengths *before* allocating;
//! * all 256 tag values over every corpus payload: no panic, unknown tags
//!   named in the error;
//! * random bit flips over tag + payload bytes: no panic (decode may
//!   succeed — a flipped f64 bit is still a valid frame — or fail with a
//!   descriptive error);
//! * inner (payload-level) length inflation is caught as a short frame.

use gdkron::gram::wire::{
    read_frame, read_frame_opt, AppendFrame, CoordFrame, SyncFrame, WorkerFrame, WIRE_MAGIC,
    WIRE_VERSION,
};
use gdkron::gram::Metric;
use gdkron::kernels::KernelClass;
use gdkron::linalg::Mat;
use gdkron::rng::Rng;

fn sync_frame() -> Box<SyncFrame> {
    Box::new(SyncFrame {
        shard_id: 1,
        nshards: 3,
        class: KernelClass::Stationary,
        metric: Metric::Diag(vec![0.5, 2.0, -0.0]),
        xt: Mat::from_fn(3, 2, |i, j| (i as f64) - 0.5 * (j as f64)),
        lam_xt: Mat::from_fn(3, 2, |i, j| (i * j) as f64 + 0.25),
        kp_eff: Mat::from_fn(2, 2, |i, j| (i + 2 * j) as f64 * 0.1),
        kpp_eff: Mat::from_fn(2, 2, |i, j| (2 * i + j) as f64 * -0.2),
        h: Mat::from_fn(2, 2, |_, _| f64::MIN_POSITIVE / 2.0),
    })
}

fn append_frame() -> Box<AppendFrame> {
    Box::new(AppendFrame {
        xt_new: vec![1.5, -2.5, f64::NAN],
        lam_new: vec![0.5, 1.0, 2.0],
        h_col: vec![0.1, 0.2, 0.3],
        kp_col: vec![-1.0, -2.0, -3.0],
        kpp_col: vec![4.0, 5.0, 6.0],
    })
}

/// Every coordinator frame type, one exemplar each.
fn coord_corpus() -> Vec<(&'static str, CoordFrame)> {
    vec![
        ("hello", CoordFrame::Hello { magic: WIRE_MAGIC, version: WIRE_VERSION }),
        ("sync", CoordFrame::Sync(sync_frame())),
        ("sync_at", CoordFrame::SyncAt { revision: u64::MAX - 1, sync: sync_frame() }),
        ("hborder", CoordFrame::HBorder { lam_new: vec![0.25, -0.75, 1e300] }),
        ("apply", CoordFrame::Apply { xin: Mat::from_fn(4, 2, |i, j| (i + j) as f64) }),
        ("pdiag", CoordFrame::PDiag { pdiag: Mat::from_fn(2, 3, |i, j| (i * j) as f64 - 0.5) }),
        ("append", CoordFrame::Append(append_frame())),
        ("drop_first", CoordFrame::DropFirst),
        ("shutdown", CoordFrame::Shutdown),
        ("ping", CoordFrame::Ping { nonce: 0x0123_4567_89AB_CDEF }),
        ("claim", CoordFrame::Claim { epoch: u64::MAX - 3 }),
        ("sync_at_f32", CoordFrame::SyncAtF32 { revision: u64::MAX - 2, sync: sync_frame() }),
        ("append_f32", CoordFrame::AppendF32(append_frame())),
    ]
}

/// Every worker frame type, one exemplar each.
fn worker_corpus() -> Vec<(&'static str, WorkerFrame)> {
    vec![
        ("hello_ack", WorkerFrame::HelloAck { version: WIRE_VERSION }),
        ("hborder_slice", WorkerFrame::HBorderSlice { slice: vec![1.0, -0.0, 2.5] }),
        ("diag", WorkerFrame::Diag { diag: Mat::from_fn(2, 2, |i, j| (i + j) as f64) }),
        ("out", WorkerFrame::Out { block: Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f64) }),
        ("err", WorkerFrame::Err { message: "boom × unicode ∇K∇′".into() }),
        ("pong", WorkerFrame::Pong { nonce: 42, epoch: u64::MAX, revision: 7, synced: true }),
        ("claim_ack", WorkerFrame::ClaimAck { epoch: u64::MAX - 3 }),
    ]
}

fn encode_coord(f: &CoordFrame) -> Vec<u8> {
    let mut buf = Vec::new();
    f.write_to(&mut buf).expect("encode");
    buf
}

fn encode_worker(f: &WorkerFrame) -> Vec<u8> {
    let mut buf = Vec::new();
    f.write_to(&mut buf).expect("encode");
    buf
}

/// Every valid encoding in the corpus, both directions.
fn all_encodings() -> Vec<(String, Vec<u8>)> {
    let mut out = Vec::new();
    for (name, f) in coord_corpus() {
        out.push((format!("coord:{name}"), encode_coord(&f)));
    }
    for (name, f) in worker_corpus() {
        out.push((format!("worker:{name}"), encode_worker(&f)));
    }
    out
}

#[test]
fn corpus_covers_every_frame_type() {
    // if a frame variant is added without a corpus entry, this pin fails
    // (update BOTH when the protocol grows)
    assert_eq!(coord_corpus().len(), 13, "coordinator corpus out of date");
    assert_eq!(worker_corpus().len(), 7, "worker corpus out of date");
    assert!(
        coord_corpus().iter().any(|(n, _)| *n == "ping")
            && coord_corpus().iter().any(|(n, _)| *n == "sync_at")
            && worker_corpus().iter().any(|(n, _)| *n == "pong"),
        "the v2 health frames must be fuzzed"
    );
    assert!(
        coord_corpus().iter().any(|(n, _)| *n == "claim")
            && worker_corpus().iter().any(|(n, _)| *n == "claim_ack"),
        "the v3 epoch-fence frames must be fuzzed"
    );
    assert!(
        coord_corpus().iter().any(|(n, _)| *n == "sync_at_f32")
            && coord_corpus().iter().any(|(n, _)| *n == "append_f32"),
        "the v4 mixed-tier frames must be fuzzed"
    );
}

#[test]
fn f32_tier_frames_are_smaller_by_exactly_the_narrowed_elements() {
    // size pin for the v4 frames: the f32 variants carry the identical
    // payload layout except that the tier panels travel 4 bytes/element
    // instead of 8. For the exemplars: SyncAtF32 narrows xt (3×2),
    // lam_xt (3×2) and h (2×2) = 16 elements; AppendF32 narrows xt_new
    // (3) and lam_new (3) = 6 elements. kp/kpp panels stay f64 in both.
    let sync_full = encode_coord(&CoordFrame::SyncAt { revision: 9, sync: sync_frame() });
    let sync_tier = encode_coord(&CoordFrame::SyncAtF32 { revision: 9, sync: sync_frame() });
    assert_eq!(
        sync_full.len() - sync_tier.len(),
        16 * 4,
        "SyncAtF32 must save exactly 4 bytes per tier-panel element"
    );
    let app_full = encode_coord(&CoordFrame::Append(append_frame()));
    let app_tier = encode_coord(&CoordFrame::AppendF32(append_frame()));
    assert_eq!(
        app_full.len() - app_tier.len(),
        6 * 4,
        "AppendF32 must save exactly 4 bytes per narrowed border element"
    );
}

#[test]
fn every_frame_type_roundtrips_byte_identically() {
    for (name, f) in coord_corpus() {
        let buf = encode_coord(&f);
        let mut cur = &buf[..];
        let (tag, payload) = read_frame(&mut cur).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(cur.is_empty(), "{name}: frame must consume exactly its bytes");
        let decoded = CoordFrame::decode(tag, &payload).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(encode_coord(&decoded), buf, "{name}: re-encode must be byte-identical");
    }
    for (name, f) in worker_corpus() {
        let buf = encode_worker(&f);
        let mut cur = &buf[..];
        let (tag, payload) = read_frame(&mut cur).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(cur.is_empty(), "{name}: frame must consume exactly its bytes");
        let decoded = WorkerFrame::decode(tag, &payload).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(encode_worker(&decoded), buf, "{name}: re-encode must be byte-identical");
    }
}

#[test]
fn every_truncation_is_a_clean_error() {
    for (name, buf) in all_encodings() {
        for cut in 0..buf.len() {
            let mut cur = &buf[..cut];
            let res = read_frame(&mut cur);
            assert!(
                res.is_err(),
                "{name}: truncation to {cut}/{} bytes must be an error",
                buf.len()
            );
            let msg = res.unwrap_err().to_string();
            assert!(!msg.is_empty(), "{name}: truncation error must be descriptive");
        }
    }
}

#[test]
fn length_field_inflation_is_a_clean_error() {
    for (name, buf) in all_encodings() {
        let true_len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
        for inflated in [
            true_len.saturating_add(1),
            true_len.saturating_add(100),
            u32::MAX / 2,
            u32::MAX,
        ] {
            // u32::MAX/2 and u32::MAX exceed MAX_FRAME_BYTES and must be
            // rejected BEFORE any allocation; smaller inflations read past
            // the payload and die as mid-frame errors
            let mut bad = buf.clone();
            bad[0..4].copy_from_slice(&inflated.to_le_bytes());
            let mut cur = &bad[..];
            let res = read_frame(&mut cur);
            assert!(res.is_err(), "{name}: inflated length {inflated} must be an error");
        }
    }
}

#[test]
fn inner_length_inflation_is_a_short_frame_error() {
    // the header is honest but a payload-level vector length lies: the
    // bounds-checked Dec must catch it as a short frame, not over-read
    let buf = encode_coord(&CoordFrame::HBorder { lam_new: vec![1.0, 2.0, 3.0] });
    let tag = buf[4];
    let mut payload = buf[5..].to_vec();
    payload[0..8].copy_from_slice(&(u64::MAX / 16).to_le_bytes());
    let err = CoordFrame::decode(tag, &payload).unwrap_err().to_string();
    assert!(
        err.contains("short frame") || err.contains("overflows"),
        "unexpected error: {err}"
    );
}

#[test]
fn every_tag_value_decodes_without_panicking() {
    let empty: Vec<u8> = Vec::new();
    let mut payloads: Vec<Vec<u8>> =
        all_encodings().into_iter().map(|(_, buf)| buf[5..].to_vec()).collect();
    payloads.push(empty);
    // the current tag space (update when the protocol grows — the corpus
    // coverage pin above will remind you)
    let coord_known = 0x01u8..=0x0D;
    let worker_known = 0x81u8..=0x87;
    for tag in 0u8..=255 {
        for payload in &payloads {
            // must never panic; Ok (tag happens to fit the payload) and
            // Err are both acceptable outcomes
            let _ = CoordFrame::decode(tag, payload);
            let _ = WorkerFrame::decode(tag, payload);
        }
        // a tag outside the known range must be NAMED unknown, not
        // misparsed into some other error
        if !coord_known.contains(&tag) {
            let err = CoordFrame::decode(tag, &[]).unwrap_err().to_string();
            assert!(err.contains("unknown"), "coord tag {tag:#04x}: {err}");
        }
        if !worker_known.contains(&tag) {
            let err = WorkerFrame::decode(tag, &[]).unwrap_err().to_string();
            assert!(err.contains("unknown"), "worker tag {tag:#04x}: {err}");
        }
    }
}

#[test]
fn random_bit_flips_never_panic() {
    // deterministic: same seed, same 4000 mutations on every run. Flips
    // target the tag byte and payload (the length prefix has its own
    // dedicated inflation test — flipping high length bits would only
    // exercise the allocator).
    let corpus = all_encodings();
    let mut rng = Rng::new(20260731);
    for _ in 0..4000 {
        let (_, buf) = &corpus[rng.below(corpus.len())];
        let mut bad = buf.clone();
        if bad.len() <= 5 {
            continue; // payload-less frame: only the tag byte can flip
        }
        let idx = 4 + rng.below(bad.len() - 4);
        let bit = rng.below(8) as u8;
        bad[idx] ^= 1 << bit;
        let mut cur = &bad[..];
        match read_frame(&mut cur) {
            Ok((tag, payload)) => {
                // both decoders must survive whatever came out
                let _ = CoordFrame::decode(tag, &payload);
                let _ = WorkerFrame::decode(tag, &payload);
            }
            Err(e) => assert!(!e.to_string().is_empty()),
        }
    }
}

#[test]
fn random_garbage_streams_never_panic() {
    // short random byte strings with a bounded length prefix: the reader
    // must error or parse, never panic or over-allocate
    let mut rng = Rng::new(7_654_321);
    for _ in 0..2000 {
        let len = rng.below(48);
        let mut garbage: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        if garbage.len() >= 4 {
            // keep the declared payload length small so a "successful"
            // header read allocates at most 64 KiB
            garbage[2] = 0;
            garbage[3] = 0;
        }
        let mut cur = &garbage[..];
        match read_frame_opt(&mut cur) {
            Ok(Some((tag, payload))) => {
                let _ = CoordFrame::decode(tag, &payload);
                let _ = WorkerFrame::decode(tag, &payload);
            }
            Ok(None) => {}
            Err(e) => assert!(!e.to_string().is_empty()),
        }
    }
}
