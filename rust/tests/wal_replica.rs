//! WAL → standby replay pins: the replica replays the primary's
//! observation stream through the ordinary [`OnlineGradientGp`] entry
//! points, so its state must be **bitwise** equal to the primary's —
//! including the windowed eviction sequence, across snapshot
//! compactions, and resuming over a truncated tail.

use std::sync::Arc;

use gdkron::coordinator::{Standby, WalOptions, WalPaths, WalWriter};
use gdkron::gp::{Compaction, FitMethod, FitOptions, OnlineGradientGp};
use gdkron::gram::Metric;
use gdkron::kernels::SquaredExponential;
use gdkron::linalg::Mat;
use gdkron::rng::Rng;

fn paths(tag: &str) -> WalPaths {
    let base =
        std::env::temp_dir().join(format!("gdkron-replica-{tag}-{}.wal", std::process::id()));
    let p = WalPaths::from_base(base);
    cleanup(&p);
    p
}

fn cleanup(p: &WalPaths) {
    let _ = std::fs::remove_file(&p.wal);
    let _ = std::fs::remove_file(&p.snap);
}

fn primary(d: usize, n: usize, seed: u64) -> OnlineGradientGp {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(d, n, |_, _| rng.gauss());
    let g = Mat::from_fn(d, n, |_, _| rng.gauss());
    OnlineGradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::Iso(0.7),
        &x,
        &g,
        &FitOptions::default(),
    )
    .unwrap()
}

fn standby_for(p: &WalPaths) -> Standby {
    Standby::new(p.clone(), Arc::new(SquaredExponential), FitMethod::default())
}

fn assert_bits_eq(a: &Mat, b: &Mat, what: &str) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{what}: shape mismatch");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i} differs ({x} vs {y})");
    }
}

fn assert_replica_matches(replica: &OnlineGradientGp, primary: &OnlineGradientGp) {
    assert_bits_eq(replica.gp().x(), primary.gp().x(), "X");
    assert_bits_eq(replica.gp().g(), primary.gp().g(), "G");
    assert_bits_eq(replica.gp().z(), primary.gp().z(), "Z (representer weights)");
    assert_tail_matches(replica, primary);
}

/// Both tiers pin bitwise: the compacted tail (when present) must replay
/// to the same bits as the live engine's, field for field.
fn assert_tail_matches(replica: &OnlineGradientGp, primary: &OnlineGradientGp) {
    assert_eq!(replica.tail_len(), primary.tail_len(), "tail length");
    assert_eq!(replica.compactions(), primary.compactions(), "fold count");
    let (Some(rt), Some(pt)) = (replica.gp().tail(), primary.gp().tail()) else { return };
    assert_bits_eq(&rt.xt, &pt.xt, "tail X̃");
    assert_bits_eq(&rt.lam_xt, &pt.lam_xt, "tail ΛX̃");
    assert_bits_eq(&rt.w, &pt.w, "tail W (frozen weights)");
    assert_bits_eq(&rt.at_hot, &pt.at_hot, "tail at_hot cache");
}

/// WAL-first discipline, as the serving engine drives it: log, then apply.
fn observe(wal: &mut WalWriter, eng: &mut OnlineGradientGp, x: &[f64], g: &[f64], win: usize) {
    wal.log_observe(x, g).unwrap();
    eng.observe_windowed(x, g, win).unwrap();
}

#[test]
fn standby_replays_the_live_stream_bitwise_and_resumes_the_tail() {
    let p = paths("stream");
    let mut eng = primary(4, 3, 21);
    let opts = WalOptions { fsync: false, snapshot_interval: 1_000 };
    let mut wal = WalWriter::create(p.clone(), opts, &eng, 0).unwrap();
    let mut rng = Rng::new(99);
    for _ in 0..5 {
        let x: Vec<f64> = (0..4).map(|_| rng.gauss()).collect();
        let g: Vec<f64> = (0..4).map(|_| rng.gauss()).collect();
        observe(&mut wal, &mut eng, &x, &g, 0);
    }

    let mut sb = standby_for(&p);
    let r = sb.catch_up().unwrap();
    assert_eq!(r.applied, 6, "genesis + five observes");
    assert_eq!(r.apply_errors, 0);
    assert_eq!(sb.applied_seq(), 6);
    assert_replica_matches(sb.engine().unwrap(), &eng);
    assert_eq!(sb.engine().unwrap().cold_refits(), 1, "replay must stay incremental");

    // the primary keeps streaming; the standby tails from its offset
    for _ in 0..2 {
        let x: Vec<f64> = (0..4).map(|_| rng.gauss()).collect();
        let g: Vec<f64> = (0..4).map(|_| rng.gauss()).collect();
        observe(&mut wal, &mut eng, &x, &g, 0);
    }
    let r = sb.catch_up().unwrap();
    assert_eq!((r.applied, r.skipped, r.snapshot_loaded), (2, 0, false));
    assert_replica_matches(sb.engine().unwrap(), &eng);
    cleanup(&p);
}

#[test]
fn windowed_replay_reproduces_the_eviction_sequence() {
    let p = paths("window");
    let win = 3;
    let mut eng = primary(3, 2, 22);
    let opts = WalOptions { fsync: false, snapshot_interval: 1_000 };
    let mut wal = WalWriter::create(p.clone(), opts, &eng, win).unwrap();
    let mut rng = Rng::new(7);
    // grow past the window: every observe beyond n = 3 evicts the oldest
    for _ in 0..6 {
        let x: Vec<f64> = (0..3).map(|_| rng.gauss()).collect();
        let g: Vec<f64> = (0..3).map(|_| rng.gauss()).collect();
        observe(&mut wal, &mut eng, &x, &g, win);
    }
    assert_eq!(eng.n(), win, "primary window must be saturated");

    let mut sb = standby_for(&p);
    sb.catch_up().unwrap();
    // the genesis record carries the window boundary, so the replica
    // slides at exactly the same observes the primary did
    assert_eq!(sb.window(), win);
    let replica = sb.engine().unwrap();
    assert_eq!(replica.n(), win);
    assert_replica_matches(replica, &eng);
    assert_eq!(replica.cold_refits(), 1);
    cleanup(&p);
}

#[test]
fn truncated_tail_is_benign_and_replay_resumes_over_it() {
    let p = paths("tail");
    let mut eng = primary(3, 2, 23);
    let opts = WalOptions { fsync: false, snapshot_interval: 1_000 };
    let mut wal = WalWriter::create(p.clone(), opts, &eng, 0).unwrap();
    let mut rng = Rng::new(8);
    for _ in 0..3 {
        let x: Vec<f64> = (0..3).map(|_| rng.gauss()).collect();
        let g: Vec<f64> = (0..3).map(|_| rng.gauss()).collect();
        observe(&mut wal, &mut eng, &x, &g, 0);
    }
    let full = std::fs::read(&p.wal).unwrap();

    // crash mid-append: the last record's tail never hit the disk
    std::fs::write(&p.wal, &full[..full.len() - 5]).unwrap();
    let mut sb = standby_for(&p);
    let r = sb.catch_up().unwrap();
    assert_eq!(r.applied, 3, "genesis + the two complete observes");
    assert_eq!(sb.applied_seq(), 3);

    // the append completes (primary recovered / flushed): the standby
    // picks up exactly the one record it was missing
    std::fs::write(&p.wal, &full).unwrap();
    let r = sb.catch_up().unwrap();
    assert_eq!((r.applied, r.skipped), (1, 0));
    assert_eq!(sb.applied_seq(), 4);
    assert_replica_matches(sb.engine().unwrap(), &eng);
    cleanup(&p);
}

#[test]
fn snapshot_catchup_loads_the_sidecar_and_skips_covered_records() {
    let p = paths("snap");
    let mut eng = primary(4, 2, 24);
    let opts = WalOptions { fsync: false, snapshot_interval: 2 };
    let mut wal = WalWriter::create(p.clone(), opts, &eng, 0).unwrap();
    let mut rng = Rng::new(9);
    for _ in 0..2 {
        let x: Vec<f64> = (0..4).map(|_| rng.gauss()).collect();
        let g: Vec<f64> = (0..4).map(|_| rng.gauss()).collect();
        observe(&mut wal, &mut eng, &x, &g, 0);
    }
    assert!(wal.snapshot_due());
    wal.write_snapshot(&eng).unwrap();
    let x: Vec<f64> = (0..4).map(|_| rng.gauss()).collect();
    let g: Vec<f64> = (0..4).map(|_| rng.gauss()).collect();
    observe(&mut wal, &mut eng, &x, &g, 0);

    // a fresh standby restores the snapshot, then replays only the tail
    let mut sb = standby_for(&p);
    let r = sb.catch_up().unwrap();
    assert!(r.snapshot_loaded);
    assert_eq!((r.applied, r.skipped), (1, 0), "only the post-snapshot record replays");
    assert_eq!(sb.applied_seq(), 4);
    let replica = sb.engine().unwrap();
    assert_replica_matches(replica, &eng);
    assert_eq!(replica.cold_refits(), eng.cold_refits(), "restore is not a refit");
    cleanup(&p);
}

#[test]
fn drop_first_and_set_targets_replay_bitwise() {
    let p = paths("ops");
    let mut eng = primary(3, 3, 25);
    let opts = WalOptions { fsync: false, snapshot_interval: 1_000 };
    let mut wal = WalWriter::create(p.clone(), opts, &eng, 0).unwrap();

    wal.log_drop_first().unwrap();
    eng.drop_first().unwrap();
    let mut rng = Rng::new(10);
    let g2 = Mat::from_fn(3, eng.n(), |_, _| rng.gauss());
    wal.log_set_targets(&g2).unwrap();
    eng.set_targets(&g2).unwrap();

    let mut sb = standby_for(&p);
    let r = sb.catch_up().unwrap();
    assert_eq!((r.applied, r.apply_errors), (3, 0));
    assert_replica_matches(sb.engine().unwrap(), &eng);

    // promotion hands the engine (and the recorded window) to the caller
    let (promoted, window) = sb.promote().unwrap();
    assert_eq!(window, 0);
    assert_replica_matches(&promoted, &eng);
    cleanup(&p);
}

#[test]
fn exact_compaction_replays_the_fold_sequence_bitwise() {
    // a fold is a pure function of the observe/drop barrier sequence, so
    // the WAL carries no fold records: the genesis policy bytes alone must
    // make the standby rebuild the primary's tail to the exact same bits —
    // including the tail_max-capped degrade-to-forget eviction at the end.
    let p = paths("fold");
    let win = 3;
    let mut eng = primary(3, 2, 26);
    eng.set_compaction(Compaction::Exact);
    eng.set_tail_max(4);
    let opts = WalOptions { fsync: false, snapshot_interval: 1_000 };
    let mut wal = WalWriter::create(p.clone(), opts, &eng, win).unwrap();
    let mut rng = Rng::new(11);
    // n starts at 2: the first observe just fills the window, the next
    // five each evict — four folds, then the cap degrades the fifth to
    // a plain forget
    for _ in 0..6 {
        let x: Vec<f64> = (0..3).map(|_| rng.gauss()).collect();
        let g: Vec<f64> = (0..3).map(|_| rng.gauss()).collect();
        observe(&mut wal, &mut eng, &x, &g, win);
    }
    assert_eq!(eng.n(), win);
    assert_eq!(eng.tail_len(), 4, "tail_max must cap the tail");
    assert_eq!(eng.compactions(), 4);

    let mut sb = standby_for(&p);
    let r = sb.catch_up().unwrap();
    assert_eq!(r.apply_errors, 0);
    let replica = sb.engine().unwrap();
    assert_eq!(replica.compaction(), Compaction::Exact, "genesis must carry the policy");
    assert_eq!(replica.tail_max(), 4, "genesis must carry the cap");
    assert_replica_matches(replica, &eng);
    assert_eq!(replica.cold_refits(), 1, "replay must stay incremental");

    // snapshot leg: the tail serializes verbatim (at_hot is stored, not
    // recomputed), so a snapshot-restored standby is just as bitwise
    wal.write_snapshot(&eng).unwrap();
    let x: Vec<f64> = (0..3).map(|_| rng.gauss()).collect();
    let g: Vec<f64> = (0..3).map(|_| rng.gauss()).collect();
    observe(&mut wal, &mut eng, &x, &g, win);
    let mut sb2 = standby_for(&p);
    let r = sb2.catch_up().unwrap();
    assert!(r.snapshot_loaded, "fresh standby must restore from the sidecar");
    assert_eq!((r.applied, r.apply_errors), (1, 0));
    assert_replica_matches(sb2.engine().unwrap(), &eng);
    cleanup(&p);
}
