//! Fault-injection chaos suite for the health-checked shard registry.
//!
//! Drives the full **degrade → probe → reconnect → resync → re-attach →
//! bit-identical-again** cycle through a deterministic TCP chaos proxy
//! (`tests/common/chaos_proxy.rs`) that can sever, delay, truncate and
//! corrupt wire frames at scripted protocol points. Pins the PR acceptance
//! criteria:
//!
//! * a scripted worker kill degrades the engine cleanly — no hang, a clean
//!   `anyhow` error on the observing solve, fallback output bit-identical;
//! * while degraded, streamed `append`/`drop_first` keep flowing (the
//!   serial fallback path), and the registry probes the dead address with
//!   exponential backoff;
//! * when the worker comes back (same registered address, fresh process —
//!   modeled by swapping the proxy upstream), the supervisor re-attaches
//!   within the configured probe/backoff budget at the next observe
//!   barrier: fresh connections, full panel broadcast at the current
//!   revision, recomputed shard plan;
//! * post-re-attach `apply_block` output is **bit-identical** to the
//!   single-shard reference, across shard counts {1, 2, 3};
//! * the v2 frames behave: workers track the panel revision through
//!   `SyncAt`/`Append`/`DropFirst` and report it (plus a stable
//!   hosting-session epoch) in their pongs.
//!
//! Every socket operation is bounded by a short timeout so a regression
//! fails fast instead of wedging CI.

#[path = "common/chaos_proxy.rs"]
mod chaos_proxy;

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use chaos_proxy::{ChaosProxy, Direction, FaultKind, FaultPlan};
use gdkron::gp::{FitMethod, FitOptions, OnlineGradientGp};
use gdkron::gram::remote::{probe, serve};
use gdkron::gram::wire::{CoordFrame, SyncFrame, WorkerFrame, WIRE_MAGIC, WIRE_VERSION};
use gdkron::gram::{
    GramFactors, GramOperator, Metric, RegistryConfig, RemoteOptions, ShardedGramFactors,
};
use gdkron::kernels::SquaredExponential;
use gdkron::linalg::Mat;
use gdkron::rng::Rng;
use gdkron::solvers::CgOptions;

/// Frame timeout for healthy-path endpoints: generous against CI jitter.
const TIMEOUT: Duration = Duration::from_secs(5);

/// Upper bound on "fails fast" / "re-attaches promptly": far below a hang,
/// far above CI noise.
const FAIL_FAST: Duration = Duration::from_secs(60);

fn sample(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.gauss())
}

/// A real `gdkron shard-worker` on an ephemeral loopback port.
fn spawn_worker() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let _ = serve(listener);
    });
    addr
}

/// Registry tuned for chaos tests: fast probes, fast backoff.
fn chaos_registry(addrs: Vec<String>) -> RegistryConfig {
    RegistryConfig {
        health_interval: Duration::from_millis(50),
        reconnect_backoff: Duration::from_millis(50),
        remote: RemoteOptions::with_timeout(Duration::from_secs(2)),
        ..RegistryConfig::new(addrs)
    }
}

fn assert_factors_bitwise(a: &GramFactors, b: &GramFactors, what: &str) {
    assert_eq!(a.n(), b.n(), "{what}: N");
    for (pa, pb, name) in [
        (&a.xt, &b.xt, "xt"),
        (&a.lam_xt, &b.lam_xt, "lam_xt"),
        (&a.lam_xt_t, &b.lam_xt_t, "lam_xt_t"),
        (&a.r, &b.r, "r"),
        (&a.h, &b.h, "h"),
        (&a.kp_eff, &b.kp_eff, "kp_eff"),
        (&a.kpp_eff, &b.kpp_eff, "kpp_eff"),
    ] {
        assert!((pa - pb).max_abs() == 0.0, "{what}: panel {name} diverged");
    }
}

fn assert_apply_bit_identical(
    engine: &ShardedGramFactors,
    reference: &GramFactors,
    seed: u64,
    what: &str,
) {
    let nd = reference.n() * reference.d();
    let xin = sample(nd, 2, seed);
    let mut got = Mat::zeros(nd, 2);
    engine.apply_block_into(&xin, &mut got).unwrap_or_else(|e| panic!("{what}: apply: {e}"));
    let mut want = Mat::zeros(nd, 2);
    GramOperator::new(reference).apply_block(&xin, &mut want);
    assert!((&got - &want).max_abs() == 0.0, "{what}: apply_block is not bit-identical");
}

/// The acceptance pin: scripted worker kill + restart across shard counts.
#[test]
fn kill_restart_reattach_cycle_is_bit_identical_across_shard_counts() {
    let kern = SquaredExponential;
    for s in [1usize, 2, 3] {
        let what = format!("S={s}");
        let x = sample(5, 24, 100 + s as u64);
        let seed_x = x.block(0, 0, 5, 4);
        let mut serial = GramFactors::new(&kern, &seed_x, Metric::Iso(0.6), None);
        let mut f = GramFactors::new(&kern, &seed_x, Metric::Iso(0.6), None);

        let proxies: Vec<ChaosProxy> = (0..s).map(|_| ChaosProxy::spawn(spawn_worker())).collect();
        let addrs: Vec<String> = proxies.iter().map(|p| p.addr().to_string()).collect();
        let mut engine =
            ShardedGramFactors::connect_registry(&f, chaos_registry(addrs)).expect("connect");
        assert!(engine.has_registry());
        assert!(engine.is_remote());
        assert_eq!(engine.shards(), s);

        // healthy streaming, bit-identical to the serial reference
        engine.append(&mut f, &kern, x.col(4));
        serial.append(&kern, x.col(4));
        assert_apply_bit_identical(&engine, &serial, 7, &format!("{what} pre-fault"));

        // kill worker 0 and restart it elsewhere behind the same
        // registered address (the proxy's)
        let fresh = spawn_worker();
        proxies[0].sever();
        proxies[0].set_upstream(&fresh);
        // let the proxy pumps notice the partition (they poll every 25 ms)
        // so the next apply deterministically observes dead sockets
        thread::sleep(Duration::from_millis(120));

        // the observing solve degrades cleanly: a prompt error, not a hang
        let nd = f.n() * f.d();
        let xin = sample(nd, 2, 8);
        let mut y = Mat::zeros(nd, 2);
        let t0 = Instant::now();
        let err = engine.apply_block_into(&xin, &mut y).unwrap_err().to_string();
        assert!(t0.elapsed() < FAIL_FAST, "{what}: degrade must not hang");
        assert!(err.contains("fallback"), "{what}: error should announce the fallback: {err}");
        assert!(engine.is_degraded());
        assert_apply_bit_identical(&engine, &serial, 9, &format!("{what} degraded fallback"));

        // heal the partition; streamed deltas continue THROUGH the
        // transition while the supervisor probes, reconnects and
        // re-attaches at a barrier
        proxies[0].restore();
        let deadline = Instant::now() + FAIL_FAST;
        let mut j = 5;
        let mut streamed = 0usize;
        while engine.is_degraded() && Instant::now() < deadline {
            if j < 20 {
                engine.append(&mut f, &kern, x.col(j));
                serial.append(&kern, x.col(j));
                engine.drop_first(&mut f);
                serial.drop_first();
                j += 1;
                streamed += 1;
            }
            engine.maybe_reattach(&f);
            thread::sleep(Duration::from_millis(30));
        }
        assert!(
            !engine.is_degraded(),
            "{what}: supervisor must re-attach within the probe/backoff budget \
             (reason: {:?})",
            engine.degraded_reason()
        );
        assert_eq!(engine.reattach_count(), 1, "{what}: exactly one re-attach");
        assert!(engine.probe_count() >= 1, "{what}: the registry must have probed");
        assert!(streamed > 0, "{what}: the stream must have continued while degraded");

        // post-re-attach: panels in lockstep, applies bit-identical, and
        // further streaming stays bit-identical on the pooled transport
        assert_factors_bitwise(&f, &serial, &format!("{what} post-reattach"));
        assert_apply_bit_identical(&engine, &serial, 10, &format!("{what} post-reattach"));
        for j in 20..22 {
            engine.append(&mut f, &kern, x.col(j));
            serial.append(&kern, x.col(j));
        }
        assert!(engine.degraded_reason().is_none(), "{what}: pooled streaming must stay clean");
        assert_factors_bitwise(&f, &serial, &format!("{what} post-reattach stream"));
        assert_apply_bit_identical(&engine, &serial, 11, &format!("{what} post-reattach stream"));
    }
}

#[test]
fn truncated_result_frame_degrades_cleanly() {
    let x = sample(5, 4, 31);
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(0.5), None);
    let proxy = ChaosProxy::spawn(spawn_worker());
    // frame 0 toward the coordinator is the HelloAck; the fault fires on
    // the next one — the apply's Diag — whose header then lies about its
    // payload
    proxy.script_fault(FaultPlan {
        dir: Direction::ToCoordinator,
        after_frames: 1,
        kind: FaultKind::Truncate { keep: 3 },
    });
    let engine =
        ShardedGramFactors::connect_remote(&f, &[proxy.addr().to_string()], Duration::from_secs(2))
            .expect("connect");
    let nd = f.n() * f.d();
    let xin = sample(nd, 1, 32);
    let mut y = Mat::zeros(nd, 1);
    let t0 = Instant::now();
    let err = engine.apply_block_into(&xin, &mut y).unwrap_err().to_string();
    assert!(t0.elapsed() < FAIL_FAST, "a truncated frame must not hang the reader");
    assert!(
        err.contains("mid-frame") || err.contains("short frame"),
        "error should name the framing problem: {err}"
    );
    assert!(engine.is_degraded());
    assert_apply_bit_identical(&engine, &f, 33, "truncate fallback");
}

#[test]
fn corrupted_frame_tag_degrades_cleanly() {
    let x = sample(5, 4, 41);
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(0.5), None);
    let proxy = ChaosProxy::spawn(spawn_worker());
    // byte 4 of the frame is the tag: the Diag answering the apply arrives
    // as an unknown frame type
    proxy.script_fault(FaultPlan {
        dir: Direction::ToCoordinator,
        after_frames: 1,
        kind: FaultKind::Corrupt { byte: 4 },
    });
    let engine =
        ShardedGramFactors::connect_remote(&f, &[proxy.addr().to_string()], Duration::from_secs(2))
            .expect("connect");
    let nd = f.n() * f.d();
    let xin = sample(nd, 1, 42);
    let mut y = Mat::zeros(nd, 1);
    let t0 = Instant::now();
    let err = engine.apply_block_into(&xin, &mut y).unwrap_err().to_string();
    assert!(t0.elapsed() < FAIL_FAST, "a corrupt frame must not hang the reader");
    assert!(err.contains("unknown"), "error should name the unknown tag: {err}");
    assert!(engine.is_degraded());
    assert_apply_bit_identical(&engine, &f, 43, "corrupt fallback");
}

#[test]
fn delayed_result_frame_times_out_within_the_gather_budget() {
    let x = sample(5, 4, 51);
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(0.5), None);
    let proxy = ChaosProxy::spawn(spawn_worker());
    // stall the Diag far past timeout × gather_factor: the configured
    // factor (not the default 12×, which would outlast this delay) must
    // bound the result read
    proxy.script_fault(FaultPlan {
        dir: Direction::ToCoordinator,
        after_frames: 1,
        kind: FaultKind::Delay(Duration::from_millis(2_500)),
    });
    let opts =
        RemoteOptions { timeout: Duration::from_millis(300), gather_factor: 2, claim_epoch: None };
    let engine = ShardedGramFactors::connect_remote_opts(&f, &[proxy.addr().to_string()], &opts)
        .expect("connect");
    let nd = f.n() * f.d();
    let xin = sample(nd, 1, 52);
    let mut y = Mat::zeros(nd, 1);
    let t0 = Instant::now();
    let err = engine.apply_block_into(&xin, &mut y);
    let elapsed = t0.elapsed();
    assert!(err.is_err(), "a stalled result read must time out, not succeed");
    assert!(
        elapsed < Duration::from_millis(2_400),
        "the configured 2× gather factor must bound the wait (took {elapsed:?})"
    );
    assert!(engine.is_degraded());
    assert_apply_bit_identical(&engine, &f, 53, "delay fallback");
}

/// What one streamed update did — replayed onto a mirror engine to pin
/// bit-identity through degrade + re-attach.
enum Op {
    Observe(Vec<f64>, Vec<f64>),
    ObserveWindowed(Vec<f64>, Vec<f64>, usize),
}

#[test]
fn online_engine_reattaches_at_the_observe_barrier_bit_identically() {
    let (d, w) = (5usize, 4usize);
    let x = sample(d, w + 3, 61);
    let g = sample(d, w + 3, 62);
    let opts = FitOptions {
        method: FitMethod::Iterative(CgOptions {
            rtol: 1e-10,
            max_iters: 20_000,
            ..Default::default()
        }),
        ..Default::default()
    };
    let fit = |x0: &Mat, g0: &Mat| {
        OnlineGradientGp::fit(Arc::new(SquaredExponential), Metric::Iso(0.5), x0, g0, &opts)
            .expect("fit")
    };

    let proxies: Vec<ChaosProxy> = (0..2).map(|_| ChaosProxy::spawn(spawn_worker())).collect();
    let addrs: Vec<String> = proxies.iter().map(|p| p.addr().to_string()).collect();
    let mut online = fit(&x.block(0, 0, d, w), &g.block(0, 0, d, w));
    online.set_remote_registry(chaos_registry(addrs)).expect("connect");
    assert_eq!(online.shards(), 2);

    let mut ops: Vec<Op> = Vec::new();
    fn push_observe(online: &mut OnlineGradientGp, ops: &mut Vec<Op>, xc: &[f64], gc: &[f64]) {
        online.observe(xc, gc).expect("observe");
        ops.push(Op::Observe(xc.to_vec(), gc.to_vec()));
    }

    // healthy streaming
    push_observe(&mut online, &mut ops, x.col(w), g.col(w));

    // partition one worker: streamed updates must CONTINUE (the engine
    // degrades internally to the fallback, no client-visible outage)
    proxies[0].sever();
    thread::sleep(Duration::from_millis(120)); // pumps poll every 25 ms
    push_observe(&mut online, &mut ops, x.col(w + 1), g.col(w + 1));
    push_observe(&mut online, &mut ops, x.col(w + 2), g.col(w + 2));
    assert!(online.shard_degradation().is_some(), "degradation must be visible");

    // heal the partition; every subsequent update is a re-attach barrier
    proxies[0].restore();
    let mut rng = Rng::new(63);
    let deadline = Instant::now() + FAIL_FAST;
    while online.shard_degradation().is_some() && Instant::now() < deadline {
        let xn = rng.gauss_vec(d);
        let gn = rng.gauss_vec(d);
        online.observe_windowed(&xn, &gn, w + 2).expect("observe through the transition");
        ops.push(Op::ObserveWindowed(xn, gn, w + 2));
        thread::sleep(Duration::from_millis(30));
    }
    assert!(
        online.shard_degradation().is_none(),
        "the registry must re-attach within the probe/backoff budget"
    );
    assert_eq!(online.shard_reattaches(), 1, "exactly one re-attach");
    assert!(online.shard_probes() >= 1, "probes must be counted");
    assert_eq!(online.cold_refits(), 1, "the whole cycle must stream without cold refits");

    // a post-re-attach update runs on the pooled transport again
    let xn = rng.gauss_vec(d);
    let gn = rng.gauss_vec(d);
    online.observe_windowed(&xn, &gn, w + 2).expect("post-reattach observe");
    ops.push(Op::ObserveWindowed(xn, gn, w + 2));
    assert!(online.shard_degradation().is_none());

    // bit-identity through the whole degrade → re-attach cycle: an
    // unsharded mirror replaying the exact update sequence must land on
    // the same bits (the fallback and every transport are bit-identical,
    // and warm starts see identical iterates)
    let mut mirror = fit(&x.block(0, 0, d, w), &g.block(0, 0, d, w));
    for op in &ops {
        match op {
            Op::Observe(xc, gc) => mirror.observe(xc, gc).expect("mirror observe"),
            Op::ObserveWindowed(xc, gc, win) => {
                mirror.observe_windowed(xc, gc, *win).expect("mirror observe_windowed")
            }
        }
    }
    assert_eq!(online.n(), mirror.n());
    assert!(
        (online.gp().z() - mirror.gp().z()).max_abs() == 0.0,
        "representer weights must be bit-identical through the degrade/re-attach cycle"
    );
    let xq = sample(d, 1, 64);
    assert_eq!(
        online.gp().predict_gradient(xq.col(0)),
        mirror.gp().predict_gradient(xq.col(0)),
        "predictions must be bit-identical through the degrade/re-attach cycle"
    );
}

#[test]
fn registry_file_edit_retargets_the_reattach() {
    let x = sample(4, 5, 71);
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(0.7), None);
    let proxies: Vec<ChaosProxy> = (0..2).map(|_| ChaosProxy::spawn(spawn_worker())).collect();
    let path = std::env::temp_dir()
        .join(format!("gdkron-chaos-registry-{}.txt", std::process::id()));
    std::fs::write(&path, format!("{}\n{}\n", proxies[0].addr(), proxies[1].addr())).unwrap();

    let cfg = RegistryConfig {
        registry_file: Some(path.clone()),
        // the file must beat this dead static list
        ..chaos_registry(vec!["127.0.0.1:1".to_string()])
    };
    let mut engine = ShardedGramFactors::connect_registry(&f, cfg).expect("connect");
    assert_eq!(engine.shards(), 2, "the registry file must beat the static list");

    // worker 0 dies for good; the operator shrinks the fleet by editing
    // the registry file — no restart anywhere
    proxies[0].sever();
    thread::sleep(Duration::from_millis(120)); // pumps poll every 25 ms
    let nd = f.n() * f.d();
    let xin = sample(nd, 1, 72);
    let mut y = Mat::zeros(nd, 1);
    assert!(engine.apply_block_into(&xin, &mut y).is_err());
    assert!(engine.is_degraded());
    std::fs::write(&path, format!("{}\n", proxies[1].addr())).unwrap();

    let deadline = Instant::now() + FAIL_FAST;
    while engine.is_degraded() && Instant::now() < deadline {
        engine.maybe_reattach(&f);
        thread::sleep(Duration::from_millis(30));
    }
    assert!(!engine.is_degraded(), "re-attach must follow the edited membership");
    assert_eq!(engine.shards(), 1, "the shard plan must be recomputed for the new membership");
    assert_apply_bit_identical(&engine, &f, 73, "re-targeted membership");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn worker_tracks_panel_revision_and_epoch() {
    let addr = spawn_worker();

    // detached probes: same worker ⇒ same epoch, no synced mirror
    let p1 = probe(&addr, TIMEOUT).expect("probe");
    let p2 = probe(&addr, TIMEOUT).expect("probe");
    assert_eq!(p1.version, WIRE_VERSION);
    assert_eq!(p1.epoch, p2.epoch, "one hosting session ⇒ one epoch");
    assert!(!p1.synced, "a probe connection never sees a synced mirror");
    assert_eq!(p1.revision, 0);
    // a different worker is a different hosting session
    let other = probe(&spawn_worker(), TIMEOUT).expect("probe");
    assert_ne!(other.epoch, p1.epoch, "restarted/other workers must change epoch");

    // data-plane revision tracking: SyncAt installs, deltas bump
    let x = sample(3, 3, 81);
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(0.5), None);
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(TIMEOUT)).unwrap();
    CoordFrame::Hello { magic: WIRE_MAGIC, version: WIRE_VERSION }.write_to(&mut stream).unwrap();
    match WorkerFrame::read_from(&mut stream).unwrap() {
        WorkerFrame::HelloAck { version } => assert_eq!(version, WIRE_VERSION),
        _ => panic!("expected HelloAck"),
    }
    let sync = Box::new(SyncFrame {
        shard_id: 0,
        nshards: 1,
        class: f.class,
        metric: f.metric.clone(),
        xt: f.xt.clone(),
        lam_xt: f.lam_xt.clone(),
        kp_eff: f.kp_eff.clone(),
        kpp_eff: f.kpp_eff.clone(),
        h: f.h.clone(),
    });
    CoordFrame::SyncAt { revision: 7, sync }.write_to(&mut stream).unwrap();

    let ping = |stream: &mut TcpStream, nonce: u64| -> (u64, u64, bool) {
        CoordFrame::Ping { nonce }.write_to(stream).unwrap();
        match WorkerFrame::read_from(stream).unwrap() {
            WorkerFrame::Pong { nonce: echoed, epoch, revision, synced } => {
                assert_eq!(echoed, nonce, "pongs must echo the probe nonce");
                (epoch, revision, synced)
            }
            _ => panic!("expected Pong"),
        }
    };
    let (epoch, rev, synced) = ping(&mut stream, 11);
    assert_eq!(epoch, p1.epoch, "data-plane pongs report the same session epoch");
    assert_eq!(rev, 7, "SyncAt must install the coordinator's revision");
    assert!(synced);

    // an O(N + D) append bumps the mirror's revision in lockstep
    let n = f.n();
    let d = f.d();
    let af = gdkron::gram::wire::AppendFrame {
        xt_new: vec![0.25; d],
        lam_new: vec![0.5; d],
        h_col: vec![0.1; n + 1],
        kp_col: vec![0.2; n + 1],
        kpp_col: vec![0.3; n + 1],
    };
    CoordFrame::Append(Box::new(af)).write_to(&mut stream).unwrap();
    let (_, rev, _) = ping(&mut stream, 12);
    assert_eq!(rev, 8, "append must bump the revision");
    CoordFrame::DropFirst.write_to(&mut stream).unwrap();
    let (_, rev, _) = ping(&mut stream, 13);
    assert_eq!(rev, 9, "drop_first must bump the revision");
    CoordFrame::Shutdown.write_to(&mut stream).unwrap();
}

#[test]
fn probe_answers_while_a_coordinator_is_attached() {
    // a worker hosting a session must still answer fresh probe
    // connections (state frames serialize on the hosting lock, pings
    // don't) — otherwise `gdkron shard-probe` would misreport healthy,
    // attached workers as dead
    let addr = spawn_worker();
    let x = sample(4, 3, 91);
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(0.5), None);
    let engine =
        ShardedGramFactors::connect_remote(&f, &[addr.clone()], TIMEOUT).expect("connect");
    let t0 = Instant::now();
    let report = probe(&addr, Duration::from_secs(2)).expect("probe while attached");
    assert!(t0.elapsed() < FAIL_FAST, "the probe answer must be prompt");
    assert!(!report.synced, "probe connections never see the session mirror");
    // and the attached session still serves, bit-identically
    assert_apply_bit_identical(&engine, &f, 92, "apply after concurrent probe");
}

#[test]
fn severed_probe_connection_fails_fast() {
    // the registry's probe against a partitioned address must fail within
    // the frame timeout — the backoff scheduler depends on prompt verdicts
    let proxy = ChaosProxy::spawn(spawn_worker());
    proxy.sever();
    let t0 = Instant::now();
    let err = probe(proxy.addr(), Duration::from_secs(2));
    assert!(err.is_err(), "a severed probe must fail");
    assert!(t0.elapsed() < FAIL_FAST, "the probe verdict must be prompt");
}
