//! End-to-end three-layer validation: the AOT artifacts produced by
//! python/JAX/Pallas (`make artifacts`) are loaded through the PJRT runtime
//! and cross-checked against the native rust implementation of the same
//! math. This is the proof that L1 (Pallas), L2 (JAX) and L3 (rust) agree.
//!
//! Requires `artifacts/manifest.toml` (skipped with a message otherwise, so
//! `cargo test` works before `make artifacts`).

use std::sync::Arc;

use gdkron::coordinator::{BatchPolicy, Engine, PjrtEngine, SurrogateServer};
use gdkron::gp::{FitOptions, GradientGp};
use gdkron::gram::{GramFactors, Metric};
use gdkron::kernels::SquaredExponential;
use gdkron::linalg::Mat;
use gdkron::rng::Rng;
use gdkron::runtime::{ArgValue, ArtifactRegistry};

fn registry() -> Option<ArtifactRegistry> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    match ArtifactRegistry::open(dir) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP: artifacts not available ({e}); run `make artifacts`");
            None
        }
    }
}

fn sample(d: usize, n: usize, seed: u64) -> (Mat, Mat) {
    let mut rng = Rng::new(seed);
    (Mat::from_fn(d, n, |_, _| rng.gauss()), Mat::from_fn(d, n, |_, _| rng.gauss()))
}

const INV_L2: f64 = 0.5;

#[test]
fn pjrt_matvec_matches_native() {
    let Some(reg) = registry() else { return };
    let (x, v) = sample(8, 4, 1);
    let got = reg
        .execute_mat(
            "smoke_matvec_d8_n4",
            &[ArgValue::Mat(&x), ArgValue::Mat(&v), ArgValue::Scalar(INV_L2)],
            8,
            4,
        )
        .unwrap();
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(INV_L2), None);
    let want = f.matvec(&v);
    let err = (&got - &want).max_abs();
    assert!(err < 1e-4 * (1.0 + want.max_abs()), "pjrt vs native matvec: {err}");
}

#[test]
fn pjrt_fit_matches_native_woodbury() {
    let Some(reg) = registry() else { return };
    let (x, g) = sample(8, 4, 2);
    let got = reg
        .execute_mat(
            "smoke_fit_d8_n4",
            &[ArgValue::Mat(&x), ArgValue::Mat(&g), ArgValue::Scalar(INV_L2)],
            8,
            4,
        )
        .unwrap();
    let gp = GradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::Iso(INV_L2),
        &x,
        &g,
        &FitOptions::default(),
    )
    .unwrap();
    let err = (&got - gp.z()).max_abs();
    assert!(err < 1e-3 * (1.0 + gp.z().max_abs()), "pjrt vs native fit: {err}");
}

#[test]
fn pjrt_predict_matches_native() {
    let Some(reg) = registry() else { return };
    let (x, g) = sample(8, 4, 3);
    let gp = GradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::Iso(INV_L2),
        &x,
        &g,
        &FitOptions::default(),
    )
    .unwrap();
    let mut rng = Rng::new(33);
    let xq = Mat::from_fn(8, 4, |_, _| rng.gauss());
    let got = reg
        .execute_mat(
            "smoke_predict_d8_n4_b4",
            &[
                ArgValue::Mat(&x),
                ArgValue::Mat(gp.z()),
                ArgValue::Mat(&xq),
                ArgValue::Scalar(INV_L2),
            ],
            8,
            4,
        )
        .unwrap();
    let want = gp.predict_gradients(&xq);
    let err = (&got - &want).max_abs();
    assert!(err < 1e-4 * (1.0 + want.max_abs()), "pjrt vs native predict: {err}");
}

#[test]
fn pjrt_engine_through_surrogate_server() {
    // the full L3 path: coordinator → batcher → PJRT engine → artifact
    if registry().is_none() {
        return;
    }
    let (x, g) = sample(8, 4, 4);
    let gp = GradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::Iso(INV_L2),
        &x,
        &g,
        &FitOptions::default(),
    )
    .unwrap();
    let z = gp.z().clone();
    let want0 = gp.predict_gradient(&vec![0.25; 8]);
    let xc = x.clone();
    let server = SurrogateServer::spawn(
        move || {
            let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
            let reg = ArtifactRegistry::open(dir)?;
            let engine = PjrtEngine::new(reg, "smoke_predict_d8_n4_b4", xc, z, INV_L2)?;
            Ok(Box::new(engine) as Box<dyn Engine>)
        },
        BatchPolicy { max_batch: 4, deadline: std::time::Duration::from_millis(1) },
    )
    .unwrap();
    let client = server.client();
    let got = client.predict(&vec![0.25; 8]).unwrap();
    for i in 0..8 {
        assert!(
            (got[i] - want0[i]).abs() < 1e-4 * (1.0 + want0[i].abs()),
            "dim {i}: {} vs {}",
            got[i],
            want0[i]
        );
    }
    // concurrent clients through the PJRT backend
    let mut handles = Vec::new();
    for t in 0..4 {
        let c = server.client();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(50 + t);
            for _ in 0..10 {
                let q = rng.gauss_vec(8);
                let r = c.predict(&q).unwrap();
                assert!(r.iter().all(|v| v.is_finite()));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.shutdown();
    assert_eq!(m.requests, 41);
    assert_eq!(m.errors, 0);
}
