//! Knob-precedence matrix for the shard/transport/registry configuration.
//!
//! Pins the documented resolution order — CLI > env > config > default —
//! for every remote-shard knob, **through the real environment** (not just
//! the injected pure cores the unit tests use):
//!
//! * `--shards` (process-global override) > `GDKRON_SHARDS` >
//!   `gram.shards` > 1;
//! * `GDKRON_REMOTE_SHARDS` > `gram.remote_shards` > empty;
//! * `GDKRON_REGISTRY_FILE` > `gram.registry_file` > unset;
//! * `gram.remote_timeout_ms` / `gram.remote_gather_factor` /
//!   `gram.health_interval_ms` / `gram.reconnect_backoff_ms` > defaults,
//!   with non-positive values rejected;
//! * `--gemm` > `GDKRON_GEMM` > `gram.gemm` > `exact`;
//! * `--precision` > `GDKRON_PRECISION` > `gram.precision` > `f64`.
//!
//! Environment-mutating cases are serialized behind a shared mutex (and
//! restore the prior value on drop), so `cargo test -q` stays race-free no
//! matter how the harness schedules this binary's threads.

use std::sync::{Mutex, MutexGuard};

use gdkron::config::{
    health_interval, reconnect_backoff, remote_gather_factor, remote_shard_timeout,
    resolve_gemm, resolve_precision, resolve_registry_file, resolve_remote_shards,
    resolve_shards, Config,
};
use gdkron::gram::remote::RESULT_TIMEOUT_FACTOR;
use gdkron::gram::sharded::{clear_global_shards, set_global_shards, MAX_SHARDS};
use gdkron::linalg::gemm::{
    clear_global_gemm, clear_global_precision, set_global_gemm, set_global_precision, GemmMode,
    Precision,
};

/// Serializes every test that touches the process environment or the
/// process-global `--shards` override.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn env_lock() -> MutexGuard<'static, ()> {
    // a poisoned lock only means another test failed; the env guards below
    // still restored their variables on unwind
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Sets an env var for the test body, restoring the previous state on drop
/// (including on panic).
struct EnvGuard {
    key: &'static str,
    prev: Option<String>,
}

impl EnvGuard {
    fn set(key: &'static str, value: &str) -> Self {
        let prev = std::env::var(key).ok();
        std::env::set_var(key, value);
        EnvGuard { key, prev }
    }

    fn unset(key: &'static str) -> Self {
        let prev = std::env::var(key).ok();
        std::env::remove_var(key);
        EnvGuard { key, prev }
    }
}

impl Drop for EnvGuard {
    fn drop(&mut self) {
        match &self.prev {
            Some(v) => std::env::set_var(self.key, v),
            None => std::env::remove_var(self.key),
        }
    }
}

#[test]
fn shards_cli_beats_env_beats_config_beats_default() {
    let _lock = env_lock();
    let cfg = Config::from_str("[gram]\nshards = 6\n").unwrap();

    // default: no knob anywhere → 1 (single shard)
    let _e = EnvGuard::unset("GDKRON_SHARDS");
    clear_global_shards();
    let empty = Config::from_str("").unwrap();
    assert_eq!(resolve_shards(&empty), 1);

    // config beats default
    assert_eq!(resolve_shards(&cfg), 6);

    // env beats config
    let _e2 = EnvGuard::set("GDKRON_SHARDS", "3");
    assert_eq!(resolve_shards(&cfg), 3);

    // CLI (process-global override) beats env
    set_global_shards(2);
    assert_eq!(resolve_shards(&cfg), 2);
    // CLI values clamp like every other spelling
    set_global_shards(10_000);
    assert_eq!(resolve_shards(&cfg), MAX_SHARDS);

    // clearing the override falls back to the env level again
    clear_global_shards();
    assert_eq!(resolve_shards(&cfg), 3);

    // a malformed env value falls through to the config level
    let _e3 = EnvGuard::set("GDKRON_SHARDS", "zonk");
    assert_eq!(resolve_shards(&cfg), 6);
}

#[test]
fn gemm_cli_beats_env_beats_config_beats_default() {
    let _lock = env_lock();
    let cfg = Config::from_str("[gram]\ngemm = \"fast\"\n").unwrap();

    // default: no knob anywhere → exact (every bit-identity pin intact)
    let _e = EnvGuard::unset("GDKRON_GEMM");
    clear_global_gemm();
    let empty = Config::from_str("").unwrap();
    assert_eq!(resolve_gemm(&empty), GemmMode::Exact);

    // config beats default
    assert_eq!(resolve_gemm(&cfg), GemmMode::Fast);

    // env beats config (case/whitespace-insensitive)
    let _e2 = EnvGuard::set("GDKRON_GEMM", " Exact ");
    assert_eq!(resolve_gemm(&cfg), GemmMode::Exact);

    // CLI (process-global override) beats env
    set_global_gemm(GemmMode::Fast);
    assert_eq!(resolve_gemm(&cfg), GemmMode::Fast);

    // clearing the override falls back to the env level again
    clear_global_gemm();
    assert_eq!(resolve_gemm(&cfg), GemmMode::Exact);

    // a malformed env value falls through to the config level
    let _e3 = EnvGuard::set("GDKRON_GEMM", "zonk");
    assert_eq!(resolve_gemm(&cfg), GemmMode::Fast);
    // ... and a malformed config value falls through to the default
    let bad = Config::from_str("[gram]\ngemm = \"turbo\"\n").unwrap();
    assert_eq!(resolve_gemm(&bad), GemmMode::Exact);
}

#[test]
fn precision_cli_beats_env_beats_config_beats_default() {
    let _lock = env_lock();
    let cfg = Config::from_str("[gram]\nprecision = \"mixed\"\n").unwrap();

    // default: no knob anywhere → f64 (the byte-for-byte inert tier)
    let _e = EnvGuard::unset("GDKRON_PRECISION");
    clear_global_precision();
    let empty = Config::from_str("").unwrap();
    assert_eq!(resolve_precision(&empty), Precision::F64);

    // config beats default
    assert_eq!(resolve_precision(&cfg), Precision::Mixed);

    // env beats config (case/whitespace-insensitive)
    let _e2 = EnvGuard::set("GDKRON_PRECISION", " F64 ");
    assert_eq!(resolve_precision(&cfg), Precision::F64);

    // CLI (process-global override) beats env
    set_global_precision(Precision::Mixed);
    assert_eq!(resolve_precision(&cfg), Precision::Mixed);

    // clearing the override falls back to the env level again
    clear_global_precision();
    assert_eq!(resolve_precision(&cfg), Precision::F64);

    // a malformed env value falls through to the config level
    let _e3 = EnvGuard::set("GDKRON_PRECISION", "f32");
    assert_eq!(resolve_precision(&cfg), Precision::Mixed);
    // ... and a malformed config value falls through to the default
    let bad = Config::from_str("[gram]\nprecision = \"bf16\"\n").unwrap();
    assert_eq!(resolve_precision(&bad), Precision::F64);
}

#[test]
fn remote_shards_env_beats_config_beats_default() {
    let _lock = env_lock();
    let cfg = Config::from_str("[gram]\nremote_shards = [\"a:1\", \" b:2 \", \"\"]\n").unwrap();

    let _e = EnvGuard::unset("GDKRON_REMOTE_SHARDS");
    assert_eq!(resolve_remote_shards(&cfg), vec!["a:1".to_string(), "b:2".to_string()]);

    let _e2 = EnvGuard::set("GDKRON_REMOTE_SHARDS", "x:9 , y:8");
    assert_eq!(resolve_remote_shards(&cfg), vec!["x:9".to_string(), "y:8".to_string()]);

    // a blank env value falls through to the config key
    let _e3 = EnvGuard::set("GDKRON_REMOTE_SHARDS", "   ");
    assert_eq!(resolve_remote_shards(&cfg), vec!["a:1".to_string(), "b:2".to_string()]);

    let empty = Config::from_str("").unwrap();
    let _e4 = EnvGuard::unset("GDKRON_REMOTE_SHARDS");
    assert!(resolve_remote_shards(&empty).is_empty(), "default is the in-process transport");
}

#[test]
fn registry_file_env_beats_config_beats_default() {
    let _lock = env_lock();
    let cfg = Config::from_str("[gram]\nregistry_file = \"/etc/gdkron/shards\"\n").unwrap();

    let _e = EnvGuard::unset("GDKRON_REGISTRY_FILE");
    assert_eq!(
        resolve_registry_file(&cfg),
        Some(std::path::PathBuf::from("/etc/gdkron/shards"))
    );

    let _e2 = EnvGuard::set("GDKRON_REGISTRY_FILE", " /run/gdkron/reg ");
    assert_eq!(resolve_registry_file(&cfg), Some(std::path::PathBuf::from("/run/gdkron/reg")));

    // blank env falls through, blank config means unset
    let _e3 = EnvGuard::set("GDKRON_REGISTRY_FILE", "  ");
    assert_eq!(
        resolve_registry_file(&cfg),
        Some(std::path::PathBuf::from("/etc/gdkron/shards"))
    );
    let blank = Config::from_str("[gram]\nregistry_file = \"\"\n").unwrap();
    assert_eq!(resolve_registry_file(&blank), None);
    let empty = Config::from_str("").unwrap();
    assert_eq!(resolve_registry_file(&empty), None);
}

#[test]
fn remote_timeout_config_beats_default_and_rejects_nonpositive() {
    let empty = Config::from_str("").unwrap();
    assert_eq!(remote_shard_timeout(&empty).as_millis(), 5_000);
    let cfg = Config::from_str("[gram]\nremote_timeout_ms = 250\n").unwrap();
    assert_eq!(remote_shard_timeout(&cfg).as_millis(), 250);
    for bad in ["remote_timeout_ms = 0", "remote_timeout_ms = -10"] {
        let c = Config::from_str(&format!("[gram]\n{bad}\n")).unwrap();
        assert_eq!(remote_shard_timeout(&c).as_millis(), 5_000, "{bad} must fall back");
    }
}

#[test]
fn gather_factor_config_beats_default_and_rejects_nonpositive() {
    // the promoted RESULT_TIMEOUT_FACTOR knob: default pinned to the
    // constant, zero rejected (it would turn every apply into a timeout)
    let empty = Config::from_str("").unwrap();
    assert_eq!(remote_gather_factor(&empty), RESULT_TIMEOUT_FACTOR);
    assert_eq!(RESULT_TIMEOUT_FACTOR, 12, "default gather factor is part of the contract");
    let cfg = Config::from_str("[gram]\nremote_gather_factor = 2\n").unwrap();
    assert_eq!(remote_gather_factor(&cfg), 2);
    // zero, negative, and beyond-u32 values all fall back to the default
    // (saturating a beyond-u32 factor could overflow the gather timeout)
    for bad in [
        "remote_gather_factor = 0",
        "remote_gather_factor = -3",
        "remote_gather_factor = 99999999999",
    ] {
        let c = Config::from_str(&format!("[gram]\n{bad}\n")).unwrap();
        assert_eq!(remote_gather_factor(&c), RESULT_TIMEOUT_FACTOR, "{bad} must fall back");
    }
}

#[test]
fn registry_timing_knobs_config_beats_default_and_reject_nonpositive() {
    let empty = Config::from_str("").unwrap();
    assert_eq!(health_interval(&empty).as_millis(), 1_000);
    assert_eq!(reconnect_backoff(&empty).as_millis(), 500);
    let cfg = Config::from_str("[gram]\nhealth_interval_ms = 75\nreconnect_backoff_ms = 40\n")
        .unwrap();
    assert_eq!(health_interval(&cfg).as_millis(), 75);
    assert_eq!(reconnect_backoff(&cfg).as_millis(), 40);
    for bad in ["health_interval_ms = 0", "health_interval_ms = -5"] {
        let c = Config::from_str(&format!("[gram]\n{bad}\n")).unwrap();
        assert_eq!(health_interval(&c).as_millis(), 1_000, "{bad} must fall back");
    }
    for bad in ["reconnect_backoff_ms = 0", "reconnect_backoff_ms = -7"] {
        let c = Config::from_str(&format!("[gram]\n{bad}\n")).unwrap();
        assert_eq!(reconnect_backoff(&c).as_millis(), 500, "{bad} must fall back");
    }
}
