//! `docs/CONFIG.md` ↔ [`gdkron::config::KNOBS`] sync pin.
//!
//! The configuration reference table is documentation, but it is pinned
//! like code: every knob in the registry must have exactly one table row
//! with the same CLI flag, env var and default, in the same order — and
//! no row may document a knob the registry doesn't know. Adding a knob
//! means adding it in both places or this test fails.

use gdkron::config::{Config, KNOBS};

fn config_md() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/CONFIG.md");
    std::fs::read_to_string(path).expect("docs/CONFIG.md must exist")
}

/// The knob rows of the reference table: each as its raw line plus the
/// first four cells (key, cli, env, default) — the later cells may
/// contain escaped pipes, so they are matched by `contains` instead.
fn table_rows(md: &str) -> Vec<(String, Vec<String>)> {
    md.lines()
        .filter(|l| l.starts_with("| `"))
        .map(|l| {
            let unescaped = l.replace("\\|", "\u{1}");
            let cells: Vec<String> = unescaped
                .trim_matches('|')
                .split('|')
                .map(|c| c.trim().replace('\u{1}', "|"))
                .collect();
            (l.replace("\\|", "|"), cells)
        })
        .collect()
}

fn strip_ticks(cell: &str) -> &str {
    cell.trim_matches('`')
}

#[test]
fn every_knob_has_a_doc_row_and_every_row_a_knob() {
    let md = config_md();
    let rows = table_rows(&md);
    let doc_keys: Vec<&str> = rows.iter().map(|(_, c)| strip_ticks(&c[0])).collect();
    let reg_keys: Vec<&str> = KNOBS.iter().map(|k| k.key).collect();
    assert_eq!(
        doc_keys, reg_keys,
        "docs/CONFIG.md table rows must list exactly the KNOBS keys, in registry order"
    );
}

#[test]
fn doc_rows_match_the_registry_fields() {
    let md = config_md();
    let rows = table_rows(&md);
    assert_eq!(rows.len(), KNOBS.len());
    for (knob, (line, cells)) in KNOBS.iter().zip(&rows) {
        assert!(cells.len() >= 5, "row for {} has too few cells: {line}", knob.key);
        let (cli, env, default) = (&cells[1], &cells[2], &cells[3]);
        match knob.cli {
            Some(flag) => assert_eq!(
                strip_ticks(cli),
                flag,
                "CLI cell for {} must be `{flag}`",
                knob.key
            ),
            None => assert_eq!(cli, "—", "{} has no CLI flag; cell must be —", knob.key),
        }
        match knob.env {
            Some(var) => assert_eq!(
                strip_ticks(env),
                var,
                "env cell for {} must be `{var}`",
                knob.key
            ),
            None => assert_eq!(env, "—", "{} has no env var; cell must be —", knob.key),
        }
        assert_eq!(default, knob.default, "default cell for {} drifted", knob.key);
        assert!(
            line.contains(knob.validation),
            "row for {} must state its validation rule {:?}: {line}",
            knob.key,
            knob.validation
        );
    }
}

#[test]
fn every_registry_sample_parses_and_sets_its_key() {
    // belt and braces with the in-module registry test: the samples the
    // docs lean on must stay parseable by the real config parser
    for k in KNOBS {
        let c = Config::from_str(k.sample)
            .unwrap_or_else(|e| panic!("sample for {} does not parse: {e:?}", k.key));
        assert!(
            c.str(k.key).is_some()
                || c.int(k.key).is_some()
                || c.float(k.key).is_some()
                || c.bool(k.key).is_some()
                || c.str_array(k.key).is_some(),
            "sample for {} does not set the key it documents",
            k.key
        );
    }
}
