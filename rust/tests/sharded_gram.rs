//! Integration suite for the sharded Gram operator.
//!
//! Pins the PR-level acceptance criteria:
//! * **bit-identity**: sharded `apply_block` (and single-vector `apply`)
//!   equals the single-shard [`GramOperator`] path *exactly* — zero ulps —
//!   across shard counts {1, 2, 3, 7}, for SE / Matérn-5/2 / poly(2)
//!   kernels, including after online `append`/`drop_first` sequences;
//! * **delta cost**: a sharded `append` performs exactly the same `O(N)`
//!   kernel evaluations as a serial [`GramFactors::append`] (counting
//!   kernel) — shards never re-evaluate retained entries — and `drop_first`
//!   performs none;
//! * **window invariant**: shard boundaries follow the sliding window, and
//!   per-shard panel memory stays bounded by the window size;
//! * the online engine with `set_shards(S)` streams bit-identically to the
//!   unsharded engine and keeps the rollback guarantee.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use gdkron::gp::{FitMethod, FitOptions, OnlineGradientGp};
use gdkron::gram::{GramFactors, GramOperator, Metric, ShardedGramFactors};
use gdkron::kernels::{
    AnalyticPath, KernelClass, Matern52, Poly2Kernel, ScalarKernel, SquaredExponential,
};
use gdkron::linalg::Mat;
use gdkron::rng::Rng;
use gdkron::solvers::{CgOptions, LinearOp};

const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 7];

/// Wrapper kernel that counts every scalar-derivative evaluation.
struct CountingKernel<K: ScalarKernel> {
    inner: K,
    calls: Arc<AtomicUsize>,
}

impl<K: ScalarKernel> CountingKernel<K> {
    fn new(inner: K) -> Self {
        CountingKernel { inner, calls: Arc::new(AtomicUsize::new(0)) }
    }
}

impl<K: ScalarKernel> ScalarKernel for CountingKernel<K> {
    fn class(&self) -> KernelClass {
        self.inner.class()
    }
    fn k(&self, r: f64) -> f64 {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.k(r)
    }
    fn dk(&self, r: f64) -> f64 {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.dk(r)
    }
    fn d2k(&self, r: f64) -> f64 {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.d2k(r)
    }
    fn d3k(&self, r: f64) -> f64 {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.d3k(r)
    }
    fn name(&self) -> &'static str {
        "counting-wrapper"
    }
    fn analytic_path(&self) -> AnalyticPath {
        self.inner.analytic_path()
    }
}

fn sample(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.gauss())
}

/// The kernel/metric/center matrix the whole suite sweeps.
fn cases() -> Vec<(Box<dyn ScalarKernel>, Metric, Option<Vec<f64>>, &'static str)> {
    let d = 6;
    let c: Vec<f64> = (0..d).map(|i| 0.1 * (i as f64) - 0.2).collect();
    vec![
        (Box::new(SquaredExponential), Metric::Iso(0.6), None, "se-iso"),
        (
            Box::new(SquaredExponential),
            Metric::Diag(vec![0.5, 1.0, 2.0, 0.3, 1.5, 0.9]),
            None,
            "se-diag",
        ),
        (Box::new(Matern52), Metric::Iso(0.8), None, "matern52"),
        (Box::new(Poly2Kernel), Metric::Iso(0.9), Some(c), "poly2"),
    ]
}

fn assert_bitwise_eq(got: &Mat, want: &Mat, what: &str) {
    assert_eq!((got.rows(), got.cols()), (want.rows(), want.cols()), "{what}: shape");
    assert!(
        (got - want).max_abs() == 0.0,
        "{what}: sharded result differs from the single-shard path"
    );
}

#[test]
fn apply_block_bit_identical_across_shard_counts() {
    for (kern, metric, center, label) in cases() {
        let x = sample(6, 5, 11);
        let f = GramFactors::new(kern.as_ref(), &x, metric, center.as_deref());
        let nd = f.n() * f.d();
        let stacked = sample(nd, 3, 12);
        let mut want = Mat::zeros(nd, 3);
        GramOperator::new(&f).apply_block(&stacked, &mut want);
        for s in SHARD_COUNTS {
            let engine = ShardedGramFactors::new(&f, s);
            assert_eq!(engine.shards(), s);
            let mut got = Mat::zeros(nd, 3);
            engine.apply_block_into(&stacked, &mut got).unwrap();
            assert_bitwise_eq(&got, &want, &format!("{label} S={s} apply_block"));

            // single-vector apply through the LinearOp surface
            let op = engine.operator();
            let mut y = vec![0.0; nd];
            op.apply(stacked.col(0), &mut y);
            let mut yref = vec![0.0; nd];
            GramOperator::new(&f).apply(stacked.col(0), &mut yref);
            assert_eq!(y, yref, "{label} S={s}: apply must be bit-identical");
        }
    }
}

fn assert_factors_bitwise(a: &GramFactors, b: &GramFactors, what: &str) {
    assert_eq!(a.n(), b.n(), "{what}: N");
    for (pa, pb, name) in [
        (&a.xt, &b.xt, "xt"),
        (&a.lam_xt, &b.lam_xt, "lam_xt"),
        (&a.lam_xt_t, &b.lam_xt_t, "lam_xt_t"),
        (&a.r, &b.r, "r"),
        (&a.h, &b.h, "h"),
        (&a.kp_eff, &b.kp_eff, "kp_eff"),
        (&a.kpp_eff, &b.kpp_eff, "kpp_eff"),
    ] {
        assert!((pa - pb).max_abs() == 0.0, "{what}: panel {name} diverged");
    }
}

#[test]
fn bit_identity_survives_online_append_drop_sequences() {
    // sharded append/drop must evolve the panels exactly like the serial
    // path, and the sharded apply must stay exactly equal throughout
    for (kern, metric, center, label) in cases() {
        let x = sample(6, 8, 21);
        let seed_x = x.block(0, 0, 6, 3);
        let serial = {
            let mut f = GramFactors::new(kern.as_ref(), &seed_x, metric.clone(), center.as_deref());
            // append ×3, drop ×2, append ×2 — mixed growth and window slides
            for j in 3..6 {
                f.append(kern.as_ref(), x.col(j));
            }
            f.drop_first();
            f.drop_first();
            for j in 6..8 {
                f.append(kern.as_ref(), x.col(j));
            }
            f
        };
        for s in SHARD_COUNTS {
            let mut f = GramFactors::new(kern.as_ref(), &seed_x, metric.clone(), center.as_deref());
            let mut engine = ShardedGramFactors::new(&f, s);
            for j in 3..6 {
                engine.append(&mut f, kern.as_ref(), x.col(j));
            }
            engine.drop_first(&mut f);
            engine.drop_first(&mut f);
            for j in 6..8 {
                engine.append(&mut f, kern.as_ref(), x.col(j));
            }
            assert_factors_bitwise(&f, &serial, &format!("{label} S={s}"));

            let nd = f.n() * f.d();
            let stacked = sample(nd, 2, 22);
            let mut want = Mat::zeros(nd, 2);
            GramOperator::new(&serial).apply_block(&stacked, &mut want);
            let mut got = Mat::zeros(nd, 2);
            engine.apply_block_into(&stacked, &mut got).unwrap();
            assert_bitwise_eq(&got, &want, &format!("{label} S={s} post-delta apply_block"));
        }
    }
}

#[test]
fn sharded_append_kernel_evals_match_serial_and_stay_linear() {
    // O(ND/S + N) per shard means above all: NO kernel re-evaluation in the
    // shards. A sharded append must cost exactly the serial border — 2(N+1)
    // scalar-derivative evaluations (dk + d2k per border entry) — and a
    // drop_first must cost zero, independent of the shard count.
    let (d, n) = (16, 9);
    let x = sample(d, n + 4, 31);
    let seed_x = x.block(0, 0, d, n);

    let serial_cost = {
        let counting = CountingKernel::new(SquaredExponential);
        let calls = counting.calls.clone();
        let mut f = GramFactors::new(&counting, &seed_x, Metric::Iso(0.4), None);
        calls.store(0, Ordering::Relaxed);
        f.append(&counting, x.col(n));
        calls.load(Ordering::Relaxed)
    };
    assert_eq!(serial_cost, 2 * (n + 1), "serial append border must be O(N) evaluations");

    for s in [2, 3, 7] {
        let counting = CountingKernel::new(SquaredExponential);
        let calls = counting.calls.clone();
        let mut f = GramFactors::new(&counting, &seed_x, Metric::Iso(0.4), None);
        let mut engine = ShardedGramFactors::new(&f, s);
        calls.store(0, Ordering::Relaxed);
        engine.append(&mut f, &counting, x.col(n));
        assert_eq!(
            calls.load(Ordering::Relaxed),
            serial_cost,
            "S={s}: sharded append must not re-evaluate the kernel anywhere"
        );
        calls.store(0, Ordering::Relaxed);
        engine.drop_first(&mut f);
        assert_eq!(
            calls.load(Ordering::Relaxed),
            0,
            "S={s}: drop_first slides boundaries without any kernel work"
        );
    }
}

#[test]
fn window_bounds_per_shard_memory_and_boundaries_slide() {
    let (d, w, s) = (12, 6, 3);
    let x = sample(d, w + 8, 41);
    let mut f =
        GramFactors::new(&SquaredExponential, &x.block(0, 0, d, w), Metric::Iso(0.5), None);
    let mut engine = ShardedGramFactors::new(&f, s);
    // the per-shard bound implied by the window: ceil(W+1 / S) rows of the
    // four N×B panel slices plus the B×D input rows (the +1 is the
    // append-before-drop transient)
    let bmax = (w + 1).div_ceil(s);
    let bound = 4 * (w + 1) * bmax + bmax * d;
    for j in w..w + 8 {
        engine.append(&mut f, &SquaredExponential, x.col(j));
        engine.drop_first(&mut f);
        assert_eq!(engine.n(), w, "window size drifted");
        let per_shard = engine.per_shard_memory_f64();
        assert_eq!(per_shard.len(), s);
        for (i, &m) in per_shard.iter().enumerate() {
            assert!(m <= bound, "shard {i}: {m} f64s exceeds the window bound {bound}");
        }
        // boundaries cover the window exactly, contiguously
        let plan = engine.plan();
        assert_eq!(plan.first().unwrap().0, 0);
        assert_eq!(plan.last().unwrap().1, w);
        for pair in plan.windows(2) {
            assert_eq!(pair[0].1, pair[1].0, "shard boundaries must tile the window");
        }
    }
}

#[test]
fn online_iterative_sharded_streams_bit_identical() {
    // the full serving stack: streamed observes + window slides through the
    // iterative engine, sharded vs unsharded — identical to the last bit
    let (d, w) = (10, 6);
    let x = sample(d, w + 5, 51);
    let g = sample(d, w + 5, 52);
    let opts = FitOptions {
        method: FitMethod::Iterative(CgOptions {
            rtol: 1e-10,
            max_iters: 20_000,
            ..Default::default()
        }),
        ..Default::default()
    };
    let fit = |shards: usize| {
        let mut online = OnlineGradientGp::fit(
            Arc::new(SquaredExponential),
            Metric::Iso(0.5),
            &x.block(0, 0, d, w),
            &g.block(0, 0, d, w),
            &opts,
        )
        .expect("initial fit");
        online.set_shards(shards);
        for j in w..w + 5 {
            online.observe(x.col(j), g.col(j)).expect("observe");
            online.drop_first().expect("drop");
        }
        assert_eq!(online.cold_refits(), 1, "steady state must not cold-refit");
        online
    };
    let plain = fit(1);
    for s in [2, 3] {
        let sharded = fit(s);
        assert_eq!(sharded.shards(), s);
        assert_bitwise_eq(
            sharded.gp().z(),
            plain.gp().z(),
            &format!("S={s} representer weights"),
        );
        let xq = sample(d, 1, 53);
        let ps = sharded.gp().predict_gradient(xq.col(0));
        let pp = plain.gp().predict_gradient(xq.col(0));
        assert_eq!(ps, pp, "S={s}: sharded predictions must be bit-identical");
    }
}

#[test]
fn sharded_engine_keeps_rollback_guarantee() {
    // a degenerate streamed observation must roll back without desyncing
    // the shard state — the engine keeps serving and accepting updates
    let (d, n) = (8, 4);
    let x = sample(d, n, 61);
    let g = sample(d, n, 62);
    let mut online = OnlineGradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::Iso(0.5),
        &x,
        &g,
        &FitOptions::default(),
    )
    .expect("fit");
    online.set_shards(3);
    let xq: Vec<f64> = (0..d).map(|i| 0.1 * i as f64).collect();
    let before = online.gp().predict_gradient(&xq);
    let dup = x.col(0).to_vec();
    let gd = g.col(0).to_vec();
    assert!(online.observe(&dup, &gd).is_err(), "duplicate must be rejected");
    assert_eq!(online.n(), n, "failed observe must not change N");
    let after = online.gp().predict_gradient(&xq);
    assert_eq!(before, after, "rollback must restore the posterior exactly");
    // shard state still serves and follows further deltas
    let mut rng = Rng::new(63);
    let xn = rng.gauss_vec(d);
    let gn = rng.gauss_vec(d);
    online.observe(&xn, &gn).expect("valid observe after rollback");
    assert_eq!(online.n(), n + 1);
    let probe = online.gp().predict_gradient(&xq);
    assert!(probe.iter().all(|v| v.is_finite()));
}

#[test]
fn exact_engine_from_panels_consistent_under_sharded_deltas() {
    // the exact (Woodbury) serving path reads the retained H panel; sharded
    // appends must leave it exactly what from_panels expects
    let (d, w) = (7, 5);
    let x = sample(d, w + 3, 71);
    let g = sample(d, w + 3, 72);
    let mut online = OnlineGradientGp::fit(
        Arc::new(Matern52),
        Metric::Iso(0.6),
        &x.block(0, 0, d, w),
        &g.block(0, 0, d, w),
        &FitOptions { method: FitMethod::Exact, ..Default::default() },
    )
    .expect("fit");
    online.set_shards(2);
    for j in w..w + 3 {
        online.observe(x.col(j), g.col(j)).expect("observe");
        online.drop_first().expect("drop");
    }
    assert_eq!(online.cold_refits(), 1);
    let cold = gdkron::gp::GradientGp::fit(
        Arc::new(Matern52),
        Metric::Iso(0.6),
        &x.block(0, 3, d, w),
        &g.block(0, 3, d, w),
        &FitOptions { method: FitMethod::Exact, ..Default::default() },
    )
    .expect("cold fit");
    let xq: Vec<f64> = (0..d).map(|i| 0.3 - 0.1 * i as f64).collect();
    let po = online.gp().predict_gradient(&xq);
    let pc = cold.predict_gradient(&xq);
    for i in 0..d {
        assert!(
            (po[i] - pc[i]).abs() < 1e-8 * (1.0 + pc[i].abs()),
            "dim {i}: {} vs {}",
            po[i],
            pc[i]
        );
    }
}
