//! Cross-implementation parity suite for the [`GradientModel`] surface.
//!
//! Every conditioning engine the crate exposes — cold [`GradientGp`],
//! [`OnlineGradientGp`] grown incrementally, the same engine with its
//! Gram operator sharded in-process or across loopback-TCP workers, and
//! the tiered (hot-window + compacted-tail) posterior — must agree on
//! `predict_gradients` / `predict_gradient_cov` when conditioned on the
//! same effective data:
//!
//! * incremental growth matches a cold fit to ≤ 1e-8 relative;
//! * sharded and remote-backed engines match the unsharded engine
//!   **bitwise** (the transport pins are op-level, this suite pins them
//!   at the model surface);
//! * at a fold barrier, the tiered posterior's *mean* matches a cold fit
//!   on the **full** history, while its covariance matches a cold fit on
//!   the **hot window** — the documented frozen-representer semantics
//!   (`docs/ARCHITECTURE.md`, "Tiered posterior").

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use gdkron::gp::{
    Compaction, FitMethod, FitOptions, GradientGp, GradientModel, OnlineGradientGp,
};
use gdkron::gram::remote::serve;
use gdkron::gram::Metric;
use gdkron::kernels::SquaredExponential;
use gdkron::linalg::Mat;
use gdkron::rng::Rng;
use gdkron::solvers::CgOptions;

const D: usize = 6;
const TOTAL: usize = 8;
const WINDOW: usize = 4;

fn sample(seed: u64) -> (Mat, Mat) {
    let mut rng = Rng::new(seed);
    (Mat::from_fn(D, TOTAL, |_, _| rng.gauss()), Mat::from_fn(D, TOTAL, |_, _| rng.gauss()))
}

fn queries(count: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(D, count, |_, _| rng.gauss())
}

fn fit_online(x: &Mat, g: &Mat, opts: &FitOptions) -> OnlineGradientGp {
    OnlineGradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::Iso(0.3),
        &x.block(0, 0, D, WINDOW),
        &g.block(0, 0, D, WINDOW),
        opts,
    )
    .expect("initial online fit")
}

fn assert_close(a: &Mat, b: &Mat, tol: f64, label: &str) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{label}: shape");
    for j in 0..a.cols() {
        for i in 0..a.rows() {
            let (u, v) = (a[(i, j)], b[(i, j)]);
            assert!(
                (u - v).abs() <= tol * (1.0 + v.abs()),
                "{label}: ({i},{j}): {u} vs {v}"
            );
        }
    }
}

fn assert_bits_eq(a: &Mat, b: &Mat, label: &str) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{label}: shape");
    for j in 0..a.cols() {
        for i in 0..a.rows() {
            assert_eq!(
                a[(i, j)].to_bits(),
                b[(i, j)].to_bits(),
                "{label}: ({i},{j}) differs in bits: {} vs {}",
                a[(i, j)],
                b[(i, j)]
            );
        }
    }
}

/// Spawn a real shard worker on an ephemeral loopback port.
fn spawn_worker() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let _ = serve(listener);
    });
    addr
}

#[test]
fn grown_online_engine_matches_the_cold_fit_on_the_full_history() {
    let (x, g) = sample(11);
    let opts = FitOptions { method: FitMethod::Exact, ..Default::default() };
    let cold = GradientGp::fit(Arc::new(SquaredExponential), Metric::Iso(0.3), &x, &g, &opts)
        .expect("cold fit");
    let mut online = fit_online(&x, &g, &opts);
    for j in WINDOW..TOTAL {
        online.observe(x.col(j), g.col(j)).expect("observe");
    }
    let xqs = queries(5, 21);
    assert_close(&online.predict_gradients(&xqs), &cold.predict_gradients(&xqs), 1e-8, "grads");
    let xq = xqs.col(0);
    let co = online.predict_gradient_cov(xq).expect("online cov");
    let cc = cold.predict_gradient_cov(xq).expect("cold cov");
    assert_close(&co, &cc, 1e-8, "gradient cov");
}

#[test]
fn sharded_and_remote_engines_match_the_unsharded_engine_bitwise() {
    // iterative engine so the operator applications actually fan out over
    // the shard transports; same observe stream on all three engines
    let (x, g) = sample(12);
    let cg = CgOptions { rtol: 1e-12, max_iters: 50_000, ..Default::default() };
    let opts = FitOptions { method: FitMethod::Iterative(cg), ..Default::default() };
    let mut plain = fit_online(&x, &g, &opts);
    let mut sharded = fit_online(&x, &g, &opts);
    sharded.set_shards(2);
    let mut remote = fit_online(&x, &g, &opts);
    let addrs = vec![spawn_worker(), spawn_worker()];
    remote.set_remote_shards(&addrs, Duration::from_secs(5)).expect("connect remote shards");
    for j in WINDOW..TOTAL {
        plain.observe(x.col(j), g.col(j)).expect("plain observe");
        sharded.observe(x.col(j), g.col(j)).expect("sharded observe");
        remote.observe(x.col(j), g.col(j)).expect("remote observe");
    }
    assert_eq!(sharded.shards(), 2);
    assert_eq!(remote.shards(), 2);
    assert!(remote.shard_degradation().is_none(), "remote engine degraded");

    let xqs = queries(5, 22);
    let want = plain.predict_gradients(&xqs);
    assert_bits_eq(&sharded.predict_gradients(&xqs), &want, "sharded grads");
    assert_bits_eq(&remote.predict_gradients(&xqs), &want, "remote grads");
    let xq = xqs.col(0);
    let want_cov = plain.predict_gradient_cov(xq).expect("plain cov");
    let sharded_cov = sharded.predict_gradient_cov(xq).expect("sharded cov");
    let remote_cov = remote.predict_gradient_cov(xq).expect("remote cov");
    assert_bits_eq(&sharded_cov, &want_cov, "sharded cov");
    assert_bits_eq(&remote_cov, &want_cov, "remote cov");
}

#[test]
fn mixed_precision_engine_tracks_f64_and_is_bitwise_shard_invariant() {
    // The mixed leg: `gram.precision = mixed` × `gp.compaction = exact` ×
    // sharded, at the model surface. The tier kernels always run the
    // blocked fast-path products, so this leg also pins the
    // `gram.gemm = fast` interaction without mutating the process-global
    // knob (other test threads share it — hence `enable_precision_tier`).
    //
    // Two pins:
    // * mixed tracks the f64 engine within 1e-5 relative (tier rounding
    //   is ~1e-7, refinement certifies solves to 1e-10);
    // * *within* mixed mode, shard partitioning is bit-invisible — the
    //   op-level invariance pin (`gram/sharded.rs`), held end-to-end.
    let (x, g) = sample(14);
    let cg = CgOptions { rtol: 1e-12, max_iters: 50_000, ..Default::default() };
    let opts = FitOptions { method: FitMethod::Iterative(cg), ..Default::default() };

    let mut plain = fit_online(&x, &g, &opts);
    plain.set_compaction(Compaction::Exact);
    let mut mixed = fit_online(&x, &g, &opts);
    mixed.enable_precision_tier();
    mixed.set_compaction(Compaction::Exact);
    let mut mixed_sharded = fit_online(&x, &g, &opts);
    mixed_sharded.enable_precision_tier();
    mixed_sharded.set_compaction(Compaction::Exact);
    // tier first, then shards: the shard mirrors snapshot tier state
    mixed_sharded.set_shards(2);
    assert_eq!(mixed_sharded.shards(), 2);

    for j in WINDOW..TOTAL {
        plain.observe(x.col(j), g.col(j)).expect("plain observe");
        mixed.observe(x.col(j), g.col(j)).expect("mixed observe");
        mixed_sharded.observe(x.col(j), g.col(j)).expect("mixed sharded observe");
    }
    // exact-compaction folds so the tiered at_hot quantization path runs
    for _ in 0..2 {
        plain.drop_first().expect("plain fold");
        mixed.drop_first().expect("mixed fold");
        mixed_sharded.drop_first().expect("mixed sharded fold");
    }
    assert!(mixed.precision_tier_active());
    assert!(mixed_sharded.precision_tier_active());
    assert_eq!(mixed.tail_len(), 2);

    let xqs = queries(5, 24);
    let f64_grads = plain.predict_gradients(&xqs);
    let mixed_grads = mixed.predict_gradients(&xqs);
    assert_close(&mixed_grads, &f64_grads, 1e-5, "mixed grads vs f64");
    assert_bits_eq(
        &mixed_sharded.predict_gradients(&xqs),
        &mixed_grads,
        "mixed sharded grads vs mixed serial",
    );

    let xq = xqs.col(0);
    let f64_cov = plain.predict_gradient_cov(xq).expect("plain cov");
    let mixed_cov = mixed.predict_gradient_cov(xq).expect("mixed cov");
    let sharded_cov = mixed_sharded.predict_gradient_cov(xq).expect("mixed sharded cov");
    assert_close(&mixed_cov, &f64_cov, 1e-5, "mixed cov vs f64");
    assert_bits_eq(&sharded_cov, &mixed_cov, "mixed sharded cov vs mixed serial");
}

#[test]
fn tiered_posterior_mean_matches_full_history_cov_matches_hot_window() {
    let (x, g) = sample(13);
    let opts = FitOptions { method: FitMethod::Exact, ..Default::default() };

    // engine with exact compaction: every eviction folds into the tail, so
    // at the fold barrier the composite mean equals the cold fit on the
    // FULL history even though only WINDOW columns stay hot. (Folds are
    // exact until the next append — so condition on everything, then
    // evict; the interleaved observe_windowed legs live in gp/online.rs.)
    let mut tiered =
        OnlineGradientGp::fit(Arc::new(SquaredExponential), Metric::Iso(0.3), &x, &g, &opts)
            .expect("full online fit");
    tiered.set_compaction(Compaction::Exact);
    for _ in WINDOW..TOTAL {
        tiered.drop_first().expect("drop_first fold");
    }
    assert_eq!(tiered.n(), WINDOW);
    assert_eq!(tiered.tail_len(), TOTAL - WINDOW);
    assert_eq!(tiered.compactions(), (TOTAL - WINDOW) as u64);

    let cold_full =
        GradientGp::fit(Arc::new(SquaredExponential), Metric::Iso(0.3), &x, &g, &opts)
            .expect("cold full fit");
    let xqs = queries(5, 23);
    assert_close(
        &tiered.predict_gradients(&xqs),
        &cold_full.predict_gradients(&xqs),
        1e-7,
        "tiered grads vs full history",
    );

    // covariance is a hot-tier quantity by design: the tail is a frozen
    // mean-field shift, so the posterior covariance is the cold fit on the
    // hot window's inputs (targets never enter a covariance)
    let cold_window = GradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::Iso(0.3),
        &x.block(0, TOTAL - WINDOW, D, WINDOW),
        &g.block(0, TOTAL - WINDOW, D, WINDOW),
        &opts,
    )
    .expect("cold window fit");
    let xq = xqs.col(0);
    let ct = tiered.predict_gradient_cov(xq).expect("tiered cov");
    let cw = cold_window.predict_gradient_cov(xq).expect("window cov");
    assert_close(&ct, &cw, 1e-8, "tiered cov vs hot window");

    // and the default forget engine stays the pre-tail windowed posterior:
    // mean AND covariance both match the cold window fit
    let mut forget = fit_online(&x, &g, &opts);
    for j in WINDOW..TOTAL {
        forget.observe_windowed(x.col(j), g.col(j), WINDOW).expect("forget observe");
    }
    assert_eq!(forget.tail_len(), 0);
    assert_close(
        &forget.predict_gradients(&xqs),
        &cold_window.predict_gradients(&xqs),
        1e-8,
        "forget grads vs window",
    );
    let cf = forget.predict_gradient_cov(xq).expect("forget cov");
    assert_close(&cf, &cw, 1e-8, "forget cov vs window");
}
