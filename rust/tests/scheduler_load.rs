//! Concurrent-load correctness for the work-bag serving core.
//!
//! The properties under test are the scheduler's contract, not timing:
//! * observes are strict barriers — a predict enqueued after an observe
//!   completed must see the updated posterior, even with many executors
//!   and many interleaved clients;
//! * admission control rejects overload with a clean, descriptive error
//!   (never a hang, never a truncated queue);
//! * shutdown under load drains cleanly — every outstanding client gets
//!   an answer or an error, and join never wedges.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use gdkron::coordinator::{
    BatchPolicy, Engine, SchedulerOptions, SurrogateServer,
};
use gdkron::gp::{FitOptions, GradientGp};
use gdkron::gram::Metric;
use gdkron::kernels::SquaredExponential;
use gdkron::linalg::Mat;
use gdkron::rng::Rng;

/// Deterministic engine whose predictions are stamped with the number of
/// observations applied so far: `out[i][j] = version + xq[i][j]`. Lets the
/// tests read "which posterior did this predict see" straight off the
/// response. The sleeps widen the race windows the scheduler must close.
struct VersionEngine {
    dim: usize,
    version: AtomicU64,
    predict_delay: Duration,
    observe_delay: Duration,
}

impl VersionEngine {
    fn new(dim: usize, predict_delay: Duration, observe_delay: Duration) -> Self {
        Self { dim, version: AtomicU64::new(0), predict_delay, observe_delay }
    }
}

impl Engine for VersionEngine {
    fn dim(&self) -> usize {
        self.dim
    }
    fn predict_batch(&self, xq: &Mat) -> anyhow::Result<Mat> {
        std::thread::sleep(self.predict_delay);
        let v = self.version.load(Ordering::SeqCst) as f64;
        Ok(Mat::from_fn(self.dim, xq.cols(), |i, j| v + xq.col(j)[i]))
    }
    fn observe(&mut self, _x: &[f64], _g: &[f64]) -> anyhow::Result<()> {
        std::thread::sleep(self.observe_delay);
        self.version.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }
    fn name(&self) -> &'static str {
        "version-test"
    }
}

fn fit_small_gp(d: usize, n: usize, seed: u64) -> GradientGp {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(d, n, |_, _| rng.gauss());
    let g = Mat::from_fn(d, n, |_, _| rng.gauss());
    GradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::Iso(0.5),
        &x,
        &g,
        &FitOptions::default(),
    )
    .unwrap()
}

/// Barrier ordering under contention: 6 client threads interleave observes
/// and predicts against a 4-executor pool. Every predict issued after an
/// observe returned must see a posterior version at least as new as the
/// number of observes globally completed at that moment.
#[test]
fn post_observe_predicts_see_the_updated_posterior() {
    const THREADS: usize = 6;
    const ROUNDS: usize = 20;
    let d = 4;
    let server = SurrogateServer::spawn_shared(
        move || {
            let e = VersionEngine::new(
                d,
                Duration::from_micros(100),
                Duration::from_micros(300),
            );
            Ok(Box::new(e) as Box<dyn Engine + Send + Sync>)
        },
        BatchPolicy { max_batch: 4, deadline: Duration::from_micros(50) },
        SchedulerOptions { executors: 4, max_queue: 1024 },
    )
    .unwrap();

    // count of observes whose barrier has fully completed (client got Ok)
    let applied = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let client = server.client();
        let applied = applied.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(300 + t as u64);
            for _ in 0..ROUNDS {
                let xn = rng.gauss_vec(d);
                let gn = rng.gauss_vec(d);
                client.observe(&xn, &gn).unwrap();
                applied.fetch_add(1, Ordering::SeqCst);
                // any observes counted here finished BEFORE this predict
                // was enqueued — the barrier must make them visible
                let floor = applied.load(Ordering::SeqCst);
                let q = vec![0.0; d];
                let out = client.predict(&q).unwrap();
                assert_eq!(out.len(), d);
                let seen = out[0];
                for v in &out {
                    assert_eq!(*v, seen, "version stamp must be batch-consistent");
                }
                assert!(
                    seen >= floor as f64,
                    "stale read: predict saw version {seen} but {floor} observes \
                     had already completed"
                );
                assert!(seen <= (THREADS * ROUNDS) as f64);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // with all clients quiesced, the posterior reflects every observe
    let out = server.client().predict(&vec![0.0; d]).unwrap();
    assert_eq!(out[0], (THREADS * ROUNDS) as f64);

    let m = server.shutdown();
    assert_eq!(m.observes, THREADS * ROUNDS);
    assert_eq!(m.requests, THREADS * ROUNDS + 1);
    assert_eq!(m.errors, 0);
    assert_eq!(m.rejected, 0);
    assert_eq!(m.observe_latency.count(), (THREADS * ROUNDS) as u64);
}

/// Admission control: a tiny queue in front of a slow engine rejects the
/// overflow fast, with a descriptive error — and every message is either
/// served or rejected, never lost or hung.
#[test]
fn overload_is_rejected_with_a_clean_error() {
    const THREADS: usize = 8;
    const ATTEMPTS: usize = 5;
    let d = 4;
    let server = SurrogateServer::spawn_shared(
        move || {
            let e = VersionEngine::new(
                d,
                Duration::from_millis(20),
                Duration::ZERO,
            );
            Ok(Box::new(e) as Box<dyn Engine + Send + Sync>)
        },
        BatchPolicy { max_batch: 1, deadline: Duration::ZERO },
        SchedulerOptions { executors: 1, max_queue: 2 },
    )
    .unwrap();

    let gate = Arc::new(Barrier::new(THREADS));
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let client = server.client();
        let gate = gate.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(500 + t as u64);
            gate.wait(); // all threads fire into the tiny queue at once
            let (mut ok, mut rejected) = (0usize, 0usize);
            for _ in 0..ATTEMPTS {
                match client.predict(&rng.gauss_vec(d)) {
                    Ok(out) => {
                        assert_eq!(out.len(), d);
                        ok += 1;
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        assert!(
                            msg.contains("overloaded") && msg.contains("max_queue"),
                            "rejection must be descriptive, got: {msg}"
                        );
                        rejected += 1;
                    }
                }
            }
            (ok, rejected)
        }));
    }
    let mut ok = 0usize;
    let mut rejected = 0usize;
    for h in handles {
        let (o, r) = h.join().unwrap();
        ok += o;
        rejected += r;
    }

    assert_eq!(ok + rejected, THREADS * ATTEMPTS, "no message may be lost");
    assert!(
        rejected > 0,
        "8 simultaneous clients against max_queue = 2 must trip admission control"
    );
    let m = server.shutdown();
    assert_eq!(m.requests, ok, "only admitted requests reach the engine");
    assert_eq!(m.rejected, rejected as u64);
    assert_eq!(m.errors, 0, "rejections are not engine errors");
    // queue never exceeds the bound (+1 for the stop sentinel, which
    // bypasses admission so shutdown always works)
    assert!(
        m.queue_depth_max <= 3,
        "queue depth {} exceeded max_queue + stop sentinel",
        m.queue_depth_max
    );
}

/// The real engine under concurrent load: predictor threads hammer a
/// 4-executor native pool while an observer streams new gradients in.
/// Post-observe predicts at the observed point must interpolate the
/// observed gradient (the posterior-update correctness check), and no
/// request may error or be dropped.
#[test]
fn native_engine_serves_correctly_under_concurrent_load() {
    const PREDICTORS: usize = 4;
    const PREDICTS: usize = 25;
    const OBSERVES: usize = 8;
    let d = 12;
    let gp = fit_small_gp(d, 4, 42);
    let server = SurrogateServer::spawn_native_opts(
        gp,
        BatchPolicy { max_batch: 8, deadline: Duration::from_micros(100) },
        SchedulerOptions { executors: 4, max_queue: 1024 },
    )
    .unwrap();

    let mut handles = Vec::new();
    for t in 0..PREDICTORS {
        let client = server.client();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(800 + t as u64);
            for _ in 0..PREDICTS {
                let out = client.predict(&rng.gauss_vec(d)).unwrap();
                assert_eq!(out.len(), d);
                for v in &out {
                    assert!(v.is_finite(), "prediction must stay finite under load");
                }
            }
        }));
    }
    // observer: stream a gradient in, then check the posterior actually
    // moved — the served prediction at the observed point must reproduce
    // the observed gradient (gradient observations interpolate).
    let observer = server.client();
    handles.push(std::thread::spawn(move || {
        let mut rng = Rng::new(77);
        for _ in 0..OBSERVES {
            let xn = rng.gauss_vec(d);
            let gn = rng.gauss_vec(d);
            observer.observe(&xn, &gn).unwrap();
            let out = observer.predict(&xn).unwrap();
            for i in 0..d {
                assert!(
                    (out[i] - gn[i]).abs() < 1e-4,
                    "post-observe predict must interpolate the streamed gradient \
                     (component {i}: got {}, observed {})",
                    out[i],
                    gn[i]
                );
            }
        }
    }));
    for h in handles {
        h.join().unwrap();
    }

    let m = server.shutdown();
    assert_eq!(m.requests, PREDICTORS * PREDICTS + OBSERVES);
    assert_eq!(m.observes, OBSERVES);
    assert_eq!(m.errors, 0);
    assert_eq!(m.request_errors + m.observe_errors, m.errors);
    assert_eq!(m.predict_latency.count() as usize, m.requests);
}

/// Shutdown with clients still in flight: every blocked client unblocks
/// with an answer or a "stopped" error, and join returns (no hang).
#[test]
fn shutdown_under_load_never_hangs() {
    const THREADS: usize = 6;
    let d = 4;
    let server = SurrogateServer::spawn_shared(
        move || {
            let e = VersionEngine::new(
                d,
                Duration::from_millis(2),
                Duration::ZERO,
            );
            Ok(Box::new(e) as Box<dyn Engine + Send + Sync>)
        },
        BatchPolicy { max_batch: 2, deadline: Duration::from_micros(100) },
        SchedulerOptions { executors: 2, max_queue: 64 },
    )
    .unwrap();

    let mut handles = Vec::new();
    for t in 0..THREADS {
        let client = server.client();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(600 + t as u64);
            loop {
                match client.predict(&rng.gauss_vec(d)) {
                    Ok(out) => assert_eq!(out.len(), d),
                    Err(e) => {
                        let msg = format!("{e:#}");
                        assert!(
                            msg.contains("stopped"),
                            "mid-shutdown failures must say the server stopped, got: {msg}"
                        );
                        return;
                    }
                }
            }
        }));
    }
    std::thread::sleep(Duration::from_millis(50));
    let m = server.shutdown(); // clients still hammering: must not wedge
    for h in handles {
        h.join().unwrap();
    }
    assert!(m.requests > 0, "the server must have served before shutdown");
    assert_eq!(m.errors, 0);
}
