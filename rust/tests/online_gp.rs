//! Integration suite for the online conditioning engine.
//!
//! Pins the PR-level acceptance criteria:
//! * sliding-window equivalence: after W appends + drops,
//!   `OnlineGradientGp` predictions match a cold `GradientGp::fit` on the
//!   same window to ≤ 1e-8 — SE, Matérn-5/2 and poly(2) kernels, exact and
//!   iterative engines;
//! * `observe` performs `O(ND + N²)` *new-entry* work only: a counting
//!   kernel wrapper shows `O(N)` kernel evaluations per append at
//!   N=16 / D=256, far below the `O(N²)` of a cold factor rebuild;
//! * the counting wrapper doubles as the structural-dispatch check — a
//!   wrapper with a different display name still routes to the analytic
//!   poly(2) path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use gdkron::gp::{FitMethod, FitOptions, FitReport, GradientGp, GradientModel, OnlineGradientGp};
use gdkron::gram::Metric;
use gdkron::kernels::{
    AnalyticPath, KernelClass, Matern52, Poly2Kernel, ScalarKernel, SquaredExponential,
};
use gdkron::linalg::Mat;
use gdkron::rng::Rng;
use gdkron::solvers::CgOptions;

/// Wrapper kernel that counts every scalar-derivative evaluation. Forwards
/// `analytic_path` (structural dispatch) but *not* the display name.
struct CountingKernel<K: ScalarKernel> {
    inner: K,
    calls: Arc<AtomicUsize>,
}

impl<K: ScalarKernel> CountingKernel<K> {
    fn new(inner: K) -> Self {
        CountingKernel { inner, calls: Arc::new(AtomicUsize::new(0)) }
    }
}

impl<K: ScalarKernel> ScalarKernel for CountingKernel<K> {
    fn class(&self) -> KernelClass {
        self.inner.class()
    }
    fn k(&self, r: f64) -> f64 {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.k(r)
    }
    fn dk(&self, r: f64) -> f64 {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.dk(r)
    }
    fn d2k(&self, r: f64) -> f64 {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.d2k(r)
    }
    fn d3k(&self, r: f64) -> f64 {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.d3k(r)
    }
    fn name(&self) -> &'static str {
        "counting-wrapper"
    }
    fn analytic_path(&self) -> AnalyticPath {
        self.inner.analytic_path()
    }
}

fn sample(d: usize, n: usize, seed: u64) -> (Mat, Mat) {
    let mut rng = Rng::new(seed);
    (Mat::from_fn(d, n, |_, _| rng.gauss()), Mat::from_fn(d, n, |_, _| rng.gauss()))
}

/// Drive a W-point sliding window through T appends+drops and check the
/// evolved engine against a cold fit on the final window.
fn check_window_equivalence(
    kern: Arc<dyn ScalarKernel>,
    metric: Metric,
    x: &Mat,
    g: &Mat,
    w: usize,
    opts: &FitOptions,
    label: &str,
) {
    let (d, total) = (x.rows(), x.cols());
    let mut online = OnlineGradientGp::fit(
        kern.clone(),
        metric.clone(),
        &x.block(0, 0, d, w),
        &g.block(0, 0, d, w),
        opts,
    )
    .unwrap_or_else(|e| panic!("{label}: initial fit failed: {e}"));
    for j in w..total {
        online
            .observe(x.col(j), g.col(j))
            .unwrap_or_else(|e| panic!("{label}: observe {j} failed: {e}"));
        online.drop_first().unwrap_or_else(|e| panic!("{label}: drop {j} failed: {e}"));
    }
    assert_eq!(online.n(), w, "{label}: window size drifted");
    assert_eq!(online.cold_refits(), 1, "{label}: steady state must not cold-refit");

    let cold = GradientGp::fit(
        kern,
        metric,
        &x.block(0, total - w, d, w),
        &g.block(0, total - w, d, w),
        opts,
    )
    .unwrap_or_else(|e| panic!("{label}: cold fit failed: {e}"));

    let mut qrng = Rng::new(1234);
    for _ in 0..4 {
        let xq = qrng.gauss_vec(d);
        let po = online.predict_gradient(&xq); // via the GradientModel trait
        let pc = cold.predict_gradient(&xq);
        for i in 0..d {
            assert!(
                (po[i] - pc[i]).abs() <= 1e-8 * (1.0 + pc[i].abs()),
                "{label}: gradient dim {i}: {} vs {}",
                po[i],
                pc[i]
            );
        }
        let vo = online.predict_value(&xq);
        let vc = cold.predict_value(&xq);
        assert!(
            (vo - vc).abs() <= 1e-8 * (1.0 + vc.abs()),
            "{label}: value {vo} vs {vc}"
        );
        let ho = online.predict_hessian(&xq);
        let hc = cold.predict_hessian(&xq);
        assert!(
            (&ho - &hc).max_abs() <= 1e-8 * (1.0 + hc.max_abs()),
            "{label}: hessian mismatch {}",
            (&ho - &hc).max_abs()
        );
    }
}

#[test]
fn sliding_window_matches_cold_fit_exact_engine() {
    let (x, g) = sample(12, 10, 1);
    for (metric, seed_label) in
        [(Metric::Iso(0.3), "se-iso"), (Metric::Iso(0.15), "se-iso-wide")]
    {
        check_window_equivalence(
            Arc::new(SquaredExponential),
            metric,
            &x,
            &g,
            5,
            &FitOptions { method: FitMethod::Exact, ..Default::default() },
            &format!("exact/{seed_label}"),
        );
    }
    check_window_equivalence(
        Arc::new(Matern52),
        Metric::Iso(0.2),
        &x,
        &g,
        5,
        &FitOptions { method: FitMethod::Exact, ..Default::default() },
        "exact/matern52",
    );
}

#[test]
fn sliding_window_matches_cold_fit_iterative_engine() {
    let (x, g) = sample(12, 10, 2);
    let cg = CgOptions { rtol: 1e-12, max_iters: 50_000, ..Default::default() };
    check_window_equivalence(
        Arc::new(SquaredExponential),
        Metric::Iso(0.3),
        &x,
        &g,
        5,
        &FitOptions { method: FitMethod::Iterative(cg.clone()), ..Default::default() },
        "iterative/se",
    );
    check_window_equivalence(
        Arc::new(Matern52),
        Metric::Iso(0.2),
        &x,
        &g,
        5,
        &FitOptions { method: FitMethod::Iterative(cg), ..Default::default() },
        "iterative/matern52",
    );
}

#[test]
fn sliding_window_matches_cold_fit_poly2_engine() {
    // poly(2) needs gradients of an actual quadratic for a consistent system
    let d = 12;
    let mut rng = Rng::new(3);
    let a = {
        let b = Mat::from_fn(d, d, |_, _| rng.gauss());
        let mut a = b.t_matmul(&b);
        for i in 0..d {
            a[(i, i)] += d as f64;
        }
        a
    };
    let x = Mat::from_fn(d, 10, |_, _| rng.gauss());
    let g = a.matmul(&x); // ∇(½xᵀAx)
    check_window_equivalence(
        Arc::new(Poly2Kernel),
        Metric::Iso(1.0),
        &x,
        &g,
        5,
        &FitOptions::default(), // Auto resolves to the analytic path
        "poly2",
    );
}

#[test]
fn append_does_linear_kernel_work_not_quadratic() {
    // acceptance pin: at N=16 / D=256, one `observe` costs O(N) kernel
    // evaluations (only the new row/column of the panels) — a cold rebuild
    // costs O(N²). Counted through a wrapper kernel.
    let (d, n) = (256usize, 16usize);
    let (x, g) = sample(d, n + 1, 4);
    let counting = CountingKernel::new(SquaredExponential);
    let calls = counting.calls.clone();
    let metric = Metric::Iso(1.0 / (0.4 * d as f64));
    let opts = FitOptions { method: FitMethod::Exact, ..Default::default() };
    let mut online = OnlineGradientGp::fit(
        Arc::new(counting),
        metric.clone(),
        &x.block(0, 0, d, n),
        &g.block(0, 0, d, n),
        &opts,
    )
    .unwrap();
    let fit_calls = calls.swap(0, Ordering::Relaxed);
    assert!(fit_calls >= 2 * n * n, "cold fit should do O(N²) evals, did {fit_calls}");

    online.observe(x.col(n), g.col(n)).unwrap();
    let observe_calls = calls.swap(0, Ordering::Relaxed);
    assert!(
        observe_calls <= 8 * (n + 1),
        "append must do O(N) kernel evals, did {observe_calls}"
    );
    assert!(
        4 * observe_calls < fit_calls,
        "append ({observe_calls} evals) should be far below a cold rebuild ({fit_calls})"
    );
    assert_eq!(online.n(), n + 1);
    assert_eq!(online.cold_refits(), 1);

    // and the evolved state still answers exactly like a cold fit
    let counting2 = CountingKernel::new(SquaredExponential);
    let cold = GradientGp::fit(
        Arc::new(counting2),
        metric,
        &x,
        &g,
        &opts,
    )
    .unwrap();
    let xq = Rng::new(5).gauss_vec(d);
    let po = online.predict_gradient(&xq);
    let pc = cold.predict_gradient(&xq);
    for i in 0..d {
        assert!((po[i] - pc[i]).abs() <= 1e-8 * (1.0 + pc[i].abs()), "dim {i}");
    }
}

#[test]
fn counting_wrapper_still_routes_to_analytic_path() {
    // structural dispatch: the wrapper's name is "counting-wrapper", not
    // "poly2" — the analytic path must be chosen anyway.
    let d = 8;
    let mut rng = Rng::new(6);
    let a = {
        let b = Mat::from_fn(d, d, |_, _| rng.gauss());
        let mut a = b.t_matmul(&b);
        for i in 0..d {
            a[(i, i)] += d as f64;
        }
        a
    };
    let x = Mat::from_fn(d, 3, |_, _| rng.gauss());
    let g = a.matmul(&x);
    let gp = GradientGp::fit(
        Arc::new(CountingKernel::new(Poly2Kernel)),
        Metric::Iso(1.0),
        &x,
        &g,
        &FitOptions::default(),
    )
    .unwrap();
    assert!(
        matches!(gp.report(), FitReport::Poly2 { .. }),
        "wrapper kernel must route structurally, got {:?}",
        gp.report()
    );
}

#[test]
fn gradient_model_trait_unifies_both_engines() {
    // consumers can be generic over the conditioning engine
    fn query<M: GradientModel>(m: &M, xq: &[f64]) -> Vec<f64> {
        m.predict_gradient(xq)
    }
    let (x, g) = sample(6, 4, 7);
    let kern = Arc::new(SquaredExponential);
    let batch =
        GradientGp::fit(kern.clone(), Metric::Iso(0.5), &x, &g, &FitOptions::default()).unwrap();
    let online =
        OnlineGradientGp::fit(kern, Metric::Iso(0.5), &x, &g, &FitOptions::default()).unwrap();
    let xq = vec![0.3; 6];
    let a = query(&batch, &xq);
    let b = query(&online, &xq);
    for i in 0..6 {
        assert!((a[i] - b[i]).abs() < 1e-12);
    }
}
