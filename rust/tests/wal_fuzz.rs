//! WAL decode hardening, `wire_fuzz` style: the recovery path must treat
//! the log as untrusted bytes. Every truncation of every record type
//! fails cleanly (no panic, no allocation from attacker-controlled
//! lengths), inflated length fields are rejected before any buffer is
//! sized from them, and a full bit-flip sweep over a real WAL and
//! snapshot never panics.

use std::sync::Arc;

use gdkron::coordinator::wal::{
    decode_snapshot, encode_snapshot, read_wal_records, SnapshotData, WalRecord,
};
use gdkron::coordinator::{WalOptions, WalPaths, WalWriter};
use gdkron::gp::{FitOptions, OnlineGradientGp};
use gdkron::gram::Metric;
use gdkron::kernels::SquaredExponential;
use gdkron::linalg::Mat;
use gdkron::rng::Rng;

fn sample_engine(d: usize, n: usize, seed: u64) -> OnlineGradientGp {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(d, n, |_, _| rng.gauss());
    let g = Mat::from_fn(d, n, |_, _| rng.gauss());
    OnlineGradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::Iso(0.8),
        &x,
        &g,
        &FitOptions::default(),
    )
    .unwrap()
}

/// A real WAL exercising every record type: genesis + observe +
/// drop_first + set_targets. Returns the raw file bytes.
fn sample_wal_bytes(tag: &str) -> Vec<u8> {
    let base = std::env::temp_dir().join(format!("gdkron-fuzz-{tag}-{}.wal", std::process::id()));
    let paths = WalPaths::from_base(base);
    let _ = std::fs::remove_file(&paths.wal);
    let _ = std::fs::remove_file(&paths.snap);
    let engine = sample_engine(3, 2, 31);
    let opts = WalOptions { fsync: false, snapshot_interval: 1_000 };
    let mut wal = WalWriter::create(paths.clone(), opts, &engine, 2).unwrap();
    wal.log_observe(&[0.25, -1.5, 3.0], &[0.5, 0.0, -0.125]).unwrap();
    wal.log_drop_first().unwrap();
    wal.log_set_targets(&Mat::from_fn(3, 2, |i, j| (i as f64) - (j as f64) * 0.5)).unwrap();
    let bytes = std::fs::read(&paths.wal).unwrap();
    let _ = std::fs::remove_file(&paths.wal);
    let _ = std::fs::remove_file(&paths.snap);
    bytes
}

/// Split raw WAL bytes into `(tag, payload)` frames.
fn frames(bytes: &[u8]) -> Vec<(u8, Vec<u8>)> {
    let mut out = Vec::new();
    let mut pos = 0;
    while pos + 5 <= bytes.len() {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let tag = bytes[pos + 4];
        let payload = bytes[pos + 5..pos + 5 + len].to_vec();
        out.push((tag, payload));
        pos += 5 + len;
    }
    assert_eq!(pos, bytes.len(), "sample WAL must split into whole frames");
    out
}

#[test]
fn every_truncation_of_every_record_type_errors_cleanly() {
    let bytes = sample_wal_bytes("trunc");
    let recs = frames(&bytes);
    assert_eq!(recs.len(), 5, "header + genesis + observe + drop + set_targets");
    // skip the header frame: the four record payloads follow
    for (tag, payload) in &recs[1..] {
        WalRecord::decode(*tag, payload)
            .unwrap_or_else(|e| panic!("intact record {tag:#04x} must decode: {e}"));
        for cut in 0..payload.len() {
            let r = WalRecord::decode(*tag, &payload[..cut]);
            assert!(
                r.is_err(),
                "truncating record {tag:#04x} to {cut}/{} bytes must fail, not misparse",
                payload.len()
            );
        }
        // trailing garbage must fail too (decode consumes the whole payload)
        let mut padded = payload.clone();
        padded.push(0);
        assert!(WalRecord::decode(*tag, &padded).is_err(), "padded record must not decode");
    }
}

#[test]
fn every_truncation_of_a_snapshot_errors_cleanly() {
    let engine = sample_engine(3, 2, 32);
    let snap = SnapshotData {
        seq: 5,
        window: 2,
        kernel_name: engine.gp().kernel().name().to_string(),
        state: engine.export_state(),
    };
    let bytes = encode_snapshot(&snap).unwrap();
    decode_snapshot(&bytes).expect("intact snapshot must decode");
    for cut in 0..bytes.len() {
        assert!(
            decode_snapshot(&bytes[..cut]).is_err(),
            "truncating the snapshot to {cut}/{} bytes must fail",
            bytes.len()
        );
    }
    let mut padded = bytes.clone();
    padded.push(0);
    assert!(decode_snapshot(&padded).is_err(), "snapshot with trailing bytes must not decode");
}

#[test]
fn frame_length_inflation_is_rejected_before_allocation() {
    let bytes = sample_wal_bytes("len");
    // inflate the *first* frame's length field past the 1 GiB cap: the
    // scanner must reject it from the 4 length bytes alone — if it tried
    // to size a buffer from the field this test would OOM, not fail
    let mut inflated = bytes.clone();
    inflated[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
    let err = read_wal_records(&inflated).unwrap_err().to_string();
    assert!(err.contains("corrupt WAL frame"), "unexpected error: {err}");

    // inflate an *inner* length (the observe record's x-vector count):
    // the record decoder must bound it by the payload size pre-allocation
    let recs = frames(&bytes);
    let (tag, payload) = &recs[2];
    let mut huge = payload.clone();
    huge[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
    let err = WalRecord::decode(*tag, &huge).unwrap_err().to_string();
    assert!(
        err.contains("short frame") || err.contains("overflows"),
        "inflated vector length must be caught by the bounds check: {err}"
    );
}

#[test]
fn bit_flip_sweep_over_the_wal_is_panic_free() {
    let bytes = sample_wal_bytes("flip");
    for i in 0..bytes.len() {
        for bit in 0..8 {
            let mut mutated = bytes.clone();
            mutated[i] ^= 1 << bit;
            // any outcome is fine — decoded garbage or a clean error —
            // as long as the scanner neither panics nor over-allocates
            let _ = read_wal_records(&mutated);
        }
    }
}

#[test]
fn bit_flip_sweep_over_the_snapshot_is_panic_free() {
    let engine = sample_engine(2, 2, 33);
    let snap = SnapshotData {
        seq: 9,
        window: 0,
        kernel_name: engine.gp().kernel().name().to_string(),
        state: engine.export_state(),
    };
    let bytes = encode_snapshot(&snap).unwrap();
    for i in 0..bytes.len() {
        for bit in 0..8 {
            let mut mutated = bytes.clone();
            mutated[i] ^= 1 << bit;
            let _ = decode_snapshot(&mutated);
        }
    }
}
