//! Property-based tests over randomized instances.
//!
//! Substitution note (DESIGN.md §6): proptest is not in the offline
//! registry, so these use the in-tree deterministic [`Rng`] to sweep many
//! random cases per invariant — same idea, seeded and reproducible. Each
//! property runs against freshly sampled shapes, kernels, metrics and data.

use std::sync::Arc;

use gdkron::gp::{FitOptions, GradientGp};
use gdkron::gram::{woodbury_solve, GramFactors, GramOperator, MatvecWorkspace, Metric};
use gdkron::kernels::{
    ExponentialKernel, Matern32, Matern52, RationalQuadratic, ScalarKernel, SquaredExponential,
};
use gdkron::linalg::{Lu, Mat};
use gdkron::rng::Rng;
use gdkron::solvers::{cg_solve, CgOptions, JacobiPrecond, LinearOp};

fn random_kernel(rng: &mut Rng) -> Arc<dyn ScalarKernel> {
    match rng.below(5) {
        0 => Arc::new(SquaredExponential),
        1 => Arc::new(Matern32),
        2 => Arc::new(Matern52),
        3 => Arc::new(RationalQuadratic::new(0.5 + 2.0 * rng.uniform())),
        _ => Arc::new(ExponentialKernel),
    }
}

fn random_metric(rng: &mut Rng, d: usize) -> Metric {
    if rng.below(2) == 0 {
        Metric::Iso(0.1 + rng.uniform())
    } else {
        Metric::Diag((0..d).map(|_| 0.1 + rng.uniform()).collect())
    }
}

/// Dot-product kernels get a random center half the time.
fn random_center(rng: &mut Rng, kern: &dyn ScalarKernel, d: usize) -> Option<Vec<f64>> {
    use gdkron::kernels::KernelClass;
    (kern.class() == KernelClass::DotProduct && rng.below(2) == 0)
        .then(|| rng.gauss_vec(d).iter().map(|v| 0.3 * v).collect())
}

#[test]
fn property_matvec_equals_dense_gram() {
    let mut rng = Rng::new(0xA1);
    for case in 0..40 {
        let d = 2 + rng.below(7);
        let n = 1 + rng.below(5);
        let kern = random_kernel(&mut rng);
        let metric = random_metric(&mut rng, d);
        let center = random_center(&mut rng, kern.as_ref(), d);
        let x = Mat::from_fn(d, n, |_, _| rng.gauss());
        let v = Mat::from_fn(d, n, |_, _| rng.gauss());
        // exponential dot kernel can overflow for large r; damp inputs
        let f = GramFactors::new(kern.as_ref(), &x.scale(0.5), metric, center.as_deref());
        let dense = f.to_dense();
        let got = f.matvec(&v);
        let want = dense.matvec(v.as_slice());
        let scale = 1.0 + want.iter().fold(0.0_f64, |m, v| m.max(v.abs()));
        // under the GDKRON_PRECISION=mixed CI leg the constructor installs
        // the f32 tier: matvec accuracy is then bounded by storage
        // rounding (~ε_f32), not f64 summation
        let tol = if f.tier_active() { 1e-5 } else { 1e-9 };
        for (i, (g, w)) in got.as_slice().iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < tol * scale,
                "case {case} ({}, d={d}, n={n}) entry {i}: {g} vs {w}",
                kern.name()
            );
        }
    }
}

#[test]
fn property_woodbury_solves_the_system() {
    let mut rng = Rng::new(0xB2);
    let mut solved = 0;
    for case in 0..40 {
        let d = 3 + rng.below(8);
        let n = 1 + rng.below(4);
        let kern = random_kernel(&mut rng);
        let metric = random_metric(&mut rng, d);
        let center = random_center(&mut rng, kern.as_ref(), d);
        let x = Mat::from_fn(d, n, |_, _| rng.gauss());
        let g = Mat::from_fn(d, n, |_, _| rng.gauss());
        let f = GramFactors::new(kern.as_ref(), &x.scale(0.5), metric, center.as_deref());
        // random instances can be genuinely singular (that's an Err, not a
        // wrong answer); whenever the solver *claims* success the residual
        // must vanish.
        if let Ok(z) = woodbury_solve(&f, &g) {
            // residual through the tier-independent exact surface: the
            // direct solve runs on the exact panels, so its claim is
            // checked against the exact operator even when the mixed CI
            // leg has installed the f32 tier
            let mut back = Mat::zeros(f.d(), f.n());
            let mut ws = MatvecWorkspace::new(f.d(), f.n());
            f.matvec_exact(&z, &mut back, &mut ws);
            let err = (&back - &g).max_abs();
            assert!(
                err < 1e-6 * (1.0 + g.max_abs()),
                "case {case} ({}): residual {err}",
                kern.name()
            );
            solved += 1;
        }
    }
    assert!(solved >= 30, "only {solved}/40 instances solvable — suspicious");
}

#[test]
fn property_gp_interpolates_observations() {
    let mut rng = Rng::new(0xC3);
    for case in 0..25 {
        let d = 3 + rng.below(6);
        let n = 1 + rng.below(4);
        let kern = random_kernel(&mut rng);
        let metric = random_metric(&mut rng, d);
        let x = Mat::from_fn(d, n, |_, _| rng.gauss());
        let g = Mat::from_fn(d, n, |_, _| rng.gauss());
        let Ok(gp) =
            GradientGp::fit(kern.clone(), metric, &x.scale(0.6), &g, &FitOptions::default())
        else {
            continue;
        };
        for b in 0..n {
            let pred = gp.predict_gradient(gp.x().col(b));
            for i in 0..d {
                assert!(
                    (pred[i] - g[(i, b)]).abs() < 1e-5 * (1.0 + g[(i, b)].abs()),
                    "case {case} ({}): obs {b} dim {i}",
                    kern.name()
                );
            }
        }
    }
}

#[test]
fn property_hessian_is_symmetric_and_consistent() {
    let mut rng = Rng::new(0xD4);
    for _ in 0..20 {
        let d = 3 + rng.below(4);
        let n = 2 + rng.below(3);
        let kern = random_kernel(&mut rng);
        let x = Mat::from_fn(d, n, |_, _| rng.gauss());
        let g = Mat::from_fn(d, n, |_, _| rng.gauss());
        let Ok(gp) =
            GradientGp::fit(kern, Metric::Iso(0.4), &x.scale(0.6), &g, &FitOptions::default())
        else {
            continue;
        };
        let xq = rng.gauss_vec(d);
        let h = gp.predict_hessian(&xq);
        assert!((&h - &h.t()).max_abs() < 1e-10);
        // Jacobian consistency at one random coordinate
        let j = rng.below(d);
        let eps = 1e-5;
        let mut xp = xq.clone();
        let mut xm = xq.clone();
        xp[j] += eps;
        xm[j] -= eps;
        let gp_ = gp.predict_gradient(&xp);
        let gm_ = gp.predict_gradient(&xm);
        for i in 0..d {
            let fd = (gp_[i] - gm_[i]) / (2.0 * eps);
            assert!(
                (h[(i, j)] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "H[{i},{j}] = {} vs fd {fd}",
                h[(i, j)]
            );
        }
    }
}

#[test]
fn property_cg_residual_never_explodes() {
    let mut rng = Rng::new(0xE5);
    for _ in 0..20 {
        let d = 4 + rng.below(8);
        let n = 2 + rng.below(6);
        let x = Mat::from_fn(d, n, |_, _| rng.gauss());
        let f = GramFactors::with_noise(
            &SquaredExponential,
            &x,
            Metric::Iso(0.3 + rng.uniform()),
            None,
            1e-8,
        );
        let op = GramOperator::new(&f);
        let b = rng.gauss_vec(d * n);
        let res = cg_solve(
            &op,
            &b,
            None,
            &CgOptions {
                rtol: 1e-8,
                max_iters: 20 * d * n,
                precond: Some(JacobiPrecond::new(&f.gram_diag())),
                track_history: true,
            },
        );
        let r0 = res.resid_history[0];
        for (i, r) in res.resid_history.iter().enumerate() {
            assert!(r.is_finite() && *r < 100.0 * r0, "iter {i}: residual {r} vs start {r0}");
        }
        assert!(res.converged, "CG failed on an SPD system with noise");
    }
}

#[test]
fn property_gram_operator_is_symmetric() {
    // uᵀ(Av) == vᵀ(Au) for random u, v — the property CG relies on.
    let mut rng = Rng::new(0xF6);
    for _ in 0..20 {
        let d = 3 + rng.below(6);
        let n = 1 + rng.below(5);
        let kern = random_kernel(&mut rng);
        let x = Mat::from_fn(d, n, |_, _| rng.gauss());
        let f = GramFactors::new(kern.as_ref(), &x.scale(0.5), Metric::Iso(0.5), None);
        let op = GramOperator::new(&f);
        let u = rng.gauss_vec(d * n);
        let v = rng.gauss_vec(d * n);
        let mut au = vec![0.0; d * n];
        let mut av = vec![0.0; d * n];
        op.apply(&u, &mut au);
        op.apply(&v, &mut av);
        let utav: f64 = u.iter().zip(&av).map(|(a, b)| a * b).sum();
        let vtau: f64 = v.iter().zip(&au).map(|(a, b)| a * b).sum();
        let scale = utav.abs().max(vtau.abs()).max(1.0);
        // the mixed tier rounds each panel independently, so the operator
        // is symmetric only to ~ε_f32 — which is why the tiered solve path
        // is refinement-certified rather than trusted blindly
        let tol = if f.tier_active() { 2e-6 } else { 1e-9 };
        assert!(
            (utav - vtau).abs() < tol * scale,
            "{}: asymmetry {utav} vs {vtau}",
            kern.name()
        );
    }
}

#[test]
fn property_dense_and_factored_solve_agree_when_both_exist() {
    let mut rng = Rng::new(0x17);
    for _ in 0..20 {
        let d = 3 + rng.below(5);
        let n = 1 + rng.below(3);
        let kern = random_kernel(&mut rng);
        let x = Mat::from_fn(d, n, |_, _| rng.gauss());
        let g = Mat::from_fn(d, n, |_, _| rng.gauss());
        let f = GramFactors::new(kern.as_ref(), &x.scale(0.5), Metric::Iso(0.6), None);
        let dense = f.to_dense();
        let (Ok(z), Ok(lu)) = (woodbury_solve(&f, &g), Lu::factor(&dense)) else {
            continue;
        };
        let zd = lu.solve_vec(g.as_slice());
        let scale = zd.iter().fold(1.0_f64, |m, v| m.max(v.abs()));
        for (a, b) in z.as_slice().iter().zip(&zd) {
            assert!((a - b).abs() < 1e-6 * scale, "{}: {a} vs {b}", kern.name());
        }
    }
}

#[test]
fn property_config_parser_never_panics_on_garbage() {
    use gdkron::config::Config;
    let mut rng = Rng::new(0x28);
    let alphabet: Vec<char> =
        "abc=[]\"#.\n 0123456789-_eE+,xyz\t{}()!@".chars().collect();
    for _ in 0..300 {
        let len = rng.below(120);
        let s: String = (0..len).map(|_| alphabet[rng.below(alphabet.len())]).collect();
        // must return Ok or Err, never panic
        let _ = Config::from_str(&s);
    }
}

#[test]
fn property_coordinator_serves_exactly_once_per_request() {
    use gdkron::coordinator::{BatchPolicy, SurrogateServer};
    use std::time::Duration;
    let mut rng = Rng::new(0x39);
    for _ in 0..5 {
        let d = 3 + rng.below(4);
        let n = 2 + rng.below(3);
        let x = Mat::from_fn(d, n, |_, _| rng.gauss());
        let g = Mat::from_fn(d, n, |_, _| rng.gauss());
        let gp = GradientGp::fit(
            Arc::new(SquaredExponential),
            Metric::Iso(0.5),
            &x,
            &g,
            &FitOptions::default(),
        )
        .unwrap();
        let reference = GradientGp::fit(
            Arc::new(SquaredExponential),
            Metric::Iso(0.5),
            &x,
            &g,
            &FitOptions::default(),
        )
        .unwrap();
        // random batching policy — results must be invariant to it
        let policy = BatchPolicy {
            max_batch: 1 + rng.below(9),
            deadline: Duration::from_micros(rng.below(800) as u64),
        };
        let server = SurrogateServer::spawn_native(gp, policy).unwrap();
        let client = server.client();
        let total = 30;
        for _ in 0..total {
            let q = rng.gauss_vec(d);
            let got = client.predict(&q).unwrap();
            let want = reference.predict_gradient(&q);
            for i in 0..d {
                assert_eq!(got[i], want[i], "batching changed the answer");
            }
        }
        let m = server.shutdown();
        assert_eq!(m.requests, total, "request accounting broken");
        assert_eq!(m.errors, 0);
    }
}
