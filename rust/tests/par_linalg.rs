//! Property tests: the parallel product kernels (`linalg::par`) agree with
//! the serial `Mat` implementations across ragged shapes.
//!
//! The parallel layer partitions output columns over scoped workers but
//! reuses the per-column kernels of whichever gemm mode is active (exact
//! serial kernels, or the cache-blocked `linalg::gemm` core under
//! `GDKRON_GEMM=fast`), so agreement with the serial `Mat` oracles must
//! hold to ≤ 1e-12 in **both** modes, for every shape — including rows/cols
//! that are not multiples of the 4-wide unroll in `matmul_acc` or of the
//! column-block width, and the 0×k / 1×k degenerate edges the unroll tail
//! has no dedicated coverage for elsewhere. Bit-identity is a *within-mode*
//! property (thread-count invariance, pinned below); exact-mode
//! par-vs-serial bit-identity is pinned at the unit level in `linalg::par`,
//! where the mode is explicit and race-free.

use gdkron::linalg::{par, Mat};
use gdkron::rng::Rng;

fn sample(r: usize, c: usize, rng: &mut Rng) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.gauss())
}

/// Shape sweep: degenerate (0, 1), unroll boundaries (3..5, 7..9) and
/// block-ragged sizes (13, 17) — chosen so inner dims hit every tail length
/// of the 4-wide unroll and column counts don't divide evenly over workers.
const SIZES: [usize; 9] = [0, 1, 2, 3, 4, 5, 8, 13, 17];

#[test]
fn par_matmul_matches_serial_on_ragged_shapes() {
    let mut rng = Rng::new(0xB1);
    for &m in &SIZES {
        for &k in &SIZES {
            for &n in &SIZES {
                let a = sample(m, k, &mut rng);
                let b = sample(k, n, &mut rng);
                let want = a.matmul(&b);
                for t in [1, 2, 3, 4] {
                    let mut got = Mat::zeros(m, n);
                    par::matmul_into_with(&a, &b, &mut got, t);
                    assert!(
                        (&got - &want).max_abs() <= 1e-12,
                        "matmul {m}x{k}*{k}x{n} threads={t}"
                    );
                }
            }
        }
    }
}

#[test]
fn par_t_matmul_matches_serial_on_ragged_shapes() {
    let mut rng = Rng::new(0xB2);
    for &m in &SIZES {
        for &k in &SIZES {
            for &n in &SIZES {
                // a is m×k, product is aᵀ(k) × b-cols(n), shared rows m
                let a = sample(m, k, &mut rng);
                let b = sample(m, n, &mut rng);
                let want = a.t_matmul(&b);
                for t in [1, 2, 4] {
                    let mut got = Mat::zeros(k, n);
                    par::t_matmul_into_with(&a, &b, &mut got, t);
                    assert!(
                        (&got - &want).max_abs() <= 1e-12,
                        "t_matmul {m}x{k}ᵀ*{m}x{n} threads={t}"
                    );
                }
            }
        }
    }
}

#[test]
fn par_matmul_t_matches_serial_on_ragged_shapes() {
    let mut rng = Rng::new(0xB3);
    for &m in &SIZES {
        for &k in &SIZES {
            for &p in &SIZES {
                // a is m×k, b is p×k, product a·bᵀ is m×p
                let a = sample(m, k, &mut rng);
                let b = sample(p, k, &mut rng);
                let want = a.matmul_t(&b);
                for t in [1, 2, 4] {
                    let mut got = Mat::zeros(m, p);
                    par::matmul_t_into_with(&a, &b, &mut got, t);
                    assert!(
                        (&got - &want).max_abs() <= 1e-12,
                        "matmul_t {m}x{k}*{p}x{k}ᵀ threads={t}"
                    );
                }
            }
        }
    }
}

#[test]
fn par_matmul_acc_accumulates_like_serial() {
    let mut rng = Rng::new(0xB4);
    for &(m, k, n) in &[(5, 3, 7), (8, 4, 4), (9, 5, 13), (1, 1, 1), (3, 8, 2)] {
        let a = sample(m, k, &mut rng);
        let b = sample(k, n, &mut rng);
        let seed = sample(m, n, &mut rng);
        let mut want = seed.clone();
        a.matmul_acc(&b, &mut want);
        for t in [1, 2, 4] {
            let mut got = seed.clone();
            par::matmul_acc_with(&a, &b, &mut got, t);
            assert!(
                (&got - &want).max_abs() <= 1e-12,
                "matmul_acc {m}x{k}*{k}x{n} threads={t}"
            );
        }
    }
}

#[test]
fn parallel_results_are_bit_identical_across_thread_counts() {
    // stronger than the 1e-12 bound: in both gemm modes, per-element
    // arithmetic is independent of how output columns are partitioned over
    // workers, so every thread count reproduces the single-thread result
    // exactly — the property the serving path's determinism pins rest on.
    let mut rng = Rng::new(0xB5);
    let a = sample(33, 29, &mut rng);
    let b = sample(29, 31, &mut rng);
    let mut want = Mat::zeros(33, 31);
    par::matmul_into_with(&a, &b, &mut want, 1);
    for t in [2, 3, 5, 8] {
        let mut got = Mat::zeros(33, 31);
        par::matmul_into_with(&a, &b, &mut got, t);
        assert!(got == want, "parallel matmul must be thread-count invariant (t={t})");
    }
}

#[test]
fn unroll_tail_shapes_hit_every_remainder() {
    // inner dimension k ≡ 0,1,2,3 (mod 4) exercises every tail of the
    // 4-wide unroll in the shared kernel, on both serial and parallel paths.
    let mut rng = Rng::new(0xB6);
    for k in [1usize, 2, 3, 4, 5, 6, 7, 8, 9] {
        let a = sample(6, k, &mut rng);
        let b = sample(k, 3, &mut rng);
        // dense reference computed entrywise, independent of the unroll
        let want = Mat::from_fn(6, 3, |i, j| {
            (0..k).map(|kk| a[(i, kk)] * b[(kk, j)]).sum::<f64>()
        });
        let serial = a.matmul(&b);
        assert!((&serial - &want).max_abs() <= 1e-12, "serial k={k}");
        let mut par_out = Mat::zeros(6, 3);
        par::matmul_into_with(&a, &b, &mut par_out, 3);
        assert!((&par_out - &want).max_abs() <= 1e-12, "parallel k={k}");
    }
}

#[test]
fn transpose_into_variants_match_allocating_forms() {
    let mut rng = Rng::new(0xB7);
    let a = sample(7, 5, &mut rng);
    let b = sample(7, 4, &mut rng);
    let mut out = Mat::full(5, 4, f64::NAN); // must be fully overwritten
    a.t_matmul_into(&b, &mut out);
    assert!((&out - &a.t_matmul(&b)).max_abs() == 0.0);

    let c = sample(6, 5, &mut rng);
    let mut out = Mat::full(7, 6, f64::NAN);
    a.matmul_t_into(&c, &mut out);
    assert!((&out - &a.matmul_t(&c)).max_abs() == 0.0);
}

#[test]
fn auto_dispatch_crosses_parallel_threshold_correctly() {
    // large enough to engage the pool on a multicore machine; the result
    // must still match the serial product exactly.
    let mut rng = Rng::new(0xB8);
    let a = sample(96, 64, &mut rng);
    let b = sample(64, 80, &mut rng);
    let want = a.matmul(&b);
    let mut got = Mat::zeros(96, 80);
    par::matmul_into(&a, &b, &mut got);
    assert!((&got - &want).max_abs() <= 1e-12);
    let got_t = par::t_matmul(&a, &sample(96, 70, &mut rng));
    assert_eq!((got_t.rows(), got_t.cols()), (64, 70));
}
