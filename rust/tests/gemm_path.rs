//! Property pins for the two panel-gemm paths (`gram.gemm = exact | fast`).
//!
//! Shape sweep over `m, k, n ∈ {0, 1, 7, 64, 257}` — empty, degenerate,
//! sub-tile, one-tile, and multi-block (257 crosses the `KC = 256` depth
//! boundary, 64 crosses `MR`/`NR` register tiles):
//!
//! * **Exact-path bit-identity:** the serial `Mat` kernels are re-derived
//!   here as independent in-test oracles (the 4-wide SAXPY accumulation,
//!   the per-entry column dots, the k-outer rank-1 sweep — transcribed,
//!   not called) and `Mat::{matmul, t_matmul, matmul_t}` must match them
//!   **bitwise**. This pins the exact reference kernels against silent
//!   drift: every pre-existing bit-identity guarantee in the serving path
//!   rests on them.
//! * **Fast-path accuracy:** every `linalg::gemm` entry point must sit
//!   within the pinned entrywise budget `8·k·ε·(|A|·|B|)` of the exact
//!   result (the contract documented on `linalg::gemm`).
//! * **Fast-path determinism:** partitioning a product over columns (or
//!   over the transposed operand's rows) must reproduce the unpartitioned
//!   result bit-for-bit — the property the thread-count / shard-count /
//!   transport bit-identity pins rely on in fast mode.
//!
//! These tests use only the mode-free public surfaces (`Mat` methods are
//! always exact; `gemm::*` entry points are always blocked), so they are
//! independent of the process-global `gram.gemm` knob and run unchanged in
//! both CI legs.

use gdkron::linalg::{gemm, Mat};
use gdkron::rng::Rng;

const SIZES: [usize; 5] = [0, 1, 7, 64, 257];

fn sample(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(r, c, |_, _| rng.gauss())
}

// ---------------------------------------------------------------------------
// Independent oracles: the serial kernels as they were before the fast path
// landed, transcribed rather than called, so `Mat` drifting would fail here.
// ---------------------------------------------------------------------------

/// Column-major SAXPY `a·b`, 4-wide rank-1 updates with zero-skip. The
/// 4-term update is summed first and folded into the output with a single
/// add — the same rounding sequence as the production kernel.
fn oracle_matmul(a: &Mat, b: &Mat) -> Mat {
    let (m, kc, n) = (a.rows(), a.cols(), b.cols());
    let mut out = Mat::zeros(m, n);
    for j in 0..n {
        let mut k = 0;
        while k + 4 <= kc {
            let (b0, b1, b2, b3) = (b[(k, j)], b[(k + 1, j)], b[(k + 2, j)], b[(k + 3, j)]);
            if b0 == 0.0 && b1 == 0.0 && b2 == 0.0 && b3 == 0.0 {
                k += 4;
                continue;
            }
            for i in 0..m {
                let upd = a[(i, k)] * b0
                    + a[(i, k + 1)] * b1
                    + a[(i, k + 2)] * b2
                    + a[(i, k + 3)] * b3;
                out[(i, j)] += upd;
            }
            k += 4;
        }
        while k < kc {
            let bk = b[(k, j)];
            if bk != 0.0 {
                for i in 0..m {
                    out[(i, j)] += a[(i, k)] * bk;
                }
            }
            k += 1;
        }
    }
    out
}

/// `aᵀ·b` as per-entry sequential column dots (zero-initialized fold).
fn oracle_t_matmul(a: &Mat, b: &Mat) -> Mat {
    let (kc, m, n) = (a.rows(), a.cols(), b.cols());
    Mat::from_fn(m, n, |i, j| {
        let mut s = 0.0;
        for t in 0..kc {
            s += a[(t, i)] * b[(t, j)];
        }
        s
    })
}

/// `a·bᵀ` as the k-outer rank-1 sweep with zero-skip.
fn oracle_matmul_t(a: &Mat, b: &Mat) -> Mat {
    let (m, kc, n) = (a.rows(), a.cols(), b.rows());
    let mut out = Mat::zeros(m, n);
    for k in 0..kc {
        for j in 0..n {
            let bjk = b[(j, k)];
            if bjk == 0.0 {
                continue;
            }
            for i in 0..m {
                out[(i, j)] += a[(i, k)] * bjk;
            }
        }
    }
    out
}

fn assert_within_bound(fast: &Mat, exact: &Mat, abs_prod: &Mat, k: usize, what: &str) {
    assert_eq!((fast.rows(), fast.cols()), (exact.rows(), exact.cols()), "{what}: shape");
    for j in 0..fast.cols() {
        for i in 0..fast.rows() {
            let bound =
                8.0 * (k.max(1) as f64) * f64::EPSILON * abs_prod[(i, j)].abs().max(1e-300);
            let err = (fast[(i, j)] - exact[(i, j)]).abs();
            assert!(err <= bound, "{what}: entry ({i},{j}) error {err:e} > bound {bound:e}");
        }
    }
}

#[test]
fn exact_kernels_are_bit_identical_to_the_prior_serial_forms() {
    for &m in &SIZES {
        for &k in &SIZES {
            for &n in &SIZES {
                let a = sample(m, k, 1 + (m * 131 + k * 17 + n) as u64);
                let b = sample(k, n, 2 + (m + k * 29 + n * 5) as u64);
                assert!(a.matmul(&b) == oracle_matmul(&a, &b), "matmul m={m} k={k} n={n}");
                let at = sample(k, m, 3 + (m * 7 + k + n * 11) as u64);
                assert!(
                    at.t_matmul(&b) == oracle_t_matmul(&at, &b),
                    "t_matmul m={m} k={k} n={n}"
                );
                let bt = sample(n, k, 4 + (m * 3 + k * 13 + n) as u64);
                assert!(
                    a.matmul_t(&bt) == oracle_matmul_t(&a, &bt),
                    "matmul_t m={m} k={k} n={n}"
                );
            }
        }
    }
}

#[test]
fn fast_path_meets_the_pinned_error_bound_on_every_entry_point() {
    for &m in &SIZES {
        for &k in &SIZES {
            for &n in &SIZES {
                let a = sample(m, k, 10 + (m * 101 + k * 3 + n) as u64);
                let b = sample(k, n, 20 + (m + k * 7 + n * 31) as u64);
                let (aa, ab) = (a.map(f64::abs), b.map(f64::abs));

                let mut fast = Mat::zeros(m, n);
                gemm::matmul_into(&a, &b, &mut fast);
                let abs_prod = aa.matmul(&ab);
                assert_within_bound(&fast, &a.matmul(&b), &abs_prod, k, "matmul_into");

                // acc: seeded accumulate == seed + product contribution,
                // within the same budget of exact acc on the same seed
                let seed = sample(m, n, 30 + (m + n) as u64);
                let mut acc = seed.clone();
                gemm::matmul_acc(&a, &b, &mut acc);
                let mut exact_acc = seed.clone();
                a.matmul_acc(&b, &mut exact_acc);
                // the accumulator's own roundings scale with |seed| too
                let acc_abs = &seed.map(f64::abs) + &abs_prod;
                assert_within_bound(&acc, &exact_acc, &acc_abs, k, "matmul_acc");

                let at = sample(k, m, 40 + (m * 19 + k + n) as u64);
                let mut tfast = Mat::zeros(m, n);
                gemm::t_matmul_into(&at, &b, &mut tfast);
                let t_abs = at.map(f64::abs).t_matmul(&ab);
                assert_within_bound(&tfast, &at.t_matmul(&b), &t_abs, k, "t_matmul_into");

                let bt = sample(n, k, 50 + (m + k * 23 + n) as u64);
                let mut ufast = Mat::zeros(m, n);
                gemm::matmul_t_into(&a, &bt, &mut ufast);
                let u_abs = aa.matmul_t(&bt.map(f64::abs));
                assert_within_bound(&ufast, &a.matmul_t(&bt), &u_abs, k, "matmul_t_into");
            }
        }
    }
}

#[test]
fn fast_path_is_partition_invariant_bitwise() {
    // spans the KC = 256 depth boundary and both register-tile edges
    let (m, k, n) = (70, 300, 23);
    let a = sample(m, k, 7);
    let b = sample(k, n, 8);
    let mut whole = Mat::zeros(m, n);
    gemm::matmul_into(&a, &b, &mut whole);

    // column partition: any split of B's columns concatenates bitwise
    for split in [1, 7, n / 2, n - 1] {
        let (bl, br) = (b.block(0, 0, k, split), b.block(0, split, k, n - split));
        let mut cl = Mat::zeros(m, split);
        let mut cr = Mat::zeros(m, n - split);
        gemm::matmul_into(&a, &bl, &mut cl);
        gemm::matmul_into(&a, &br, &mut cr);
        assert!(cl.hcat(&cr) == whole, "column split {split} not bit-identical");
    }

    // row partition (via the transpose entry point: A's columns are the
    // output rows — the shard row-block case)
    let at = sample(k, m, 9);
    let mut twhole = Mat::zeros(m, n);
    gemm::t_matmul_into(&at, &b, &mut twhole);
    let split = 27;
    let (al, ar) = (at.block(0, 0, k, split), at.block(0, split, k, m - split));
    let mut tl = Mat::zeros(split, n);
    let mut tr = Mat::zeros(m - split, n);
    gemm::t_matmul_into(&al, &b, &mut tl);
    gemm::t_matmul_into(&ar, &b, &mut tr);
    let stacked = Mat::from_fn(m, n, |i, j| if i < split { tl[(i, j)] } else { tr[(i - split, j)] });
    assert!(stacked == twhole, "row split not bit-identical");
}
