//! Kill-the-primary failover chaos test: the full degrade → lease lapse →
//! standby replay → epoch-fenced takeover sequence, end to end, against
//! real loopback shard workers.
//!
//! The acceptance pins:
//! * the hot standby's replayed state is **bitwise** equal to an
//!   unsharded mirror of the primary — replay goes through the ordinary
//!   `OnlineGradientGp` entry points, so there is nothing to drift;
//! * takeover performs **zero cold refits** (the `cold_refits == 1`
//!   steady-state invariant survives the failover);
//! * a **zombie primary** — one that wakes up after the lease steal —
//!   cannot corrupt fleet state: its lease renewal fails with the stolen
//!   epoch, its streamed write is rejected by the workers' epoch fence
//!   ("stale coordinator epoch"), and the new primary's sharded solves
//!   stay bitwise equal to the mirror afterwards.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use gdkron::coordinator::{Standby, WalOptions, WalPaths, WalWriter};
use gdkron::gp::{Compaction, FitMethod, FitOptions, OnlineGradientGp};
use gdkron::gram::registry::{now_unix_ms, read_lease};
use gdkron::gram::remote::serve;
use gdkron::gram::{LeaseKeeper, Metric, RegistryConfig};
use gdkron::kernels::SquaredExponential;
use gdkron::linalg::Mat;
use gdkron::rng::Rng;
use gdkron::solvers::CgOptions;

/// Socket-operation bound: generous for CI, far below a hang.
const TIMEOUT: Duration = Duration::from_secs(5);
/// Primary heartbeat TTL: long enough that the live-lease assertions are
/// not racy on a loaded CI box, short enough to keep the lapse wait cheap.
const TTL: Duration = Duration::from_millis(1_000);

fn spawn_worker() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let _ = serve(listener);
    });
    addr
}

/// Iterative solves route every re-solve through the shard engine, so the
/// bitwise mirror comparison also proves the worker mirrors were never
/// corrupted — an extra column smuggled in by a zombie would change the
/// operator applications, and hence the representer weights.
fn fit_method() -> FitMethod {
    FitMethod::Iterative(CgOptions { rtol: 1e-10, max_iters: 20_000, ..Default::default() })
}

fn fit(x: &Mat, g: &Mat) -> OnlineGradientGp {
    let opts = FitOptions { method: fit_method(), ..Default::default() };
    OnlineGradientGp::fit(Arc::new(SquaredExponential), Metric::Iso(0.5), x, g, &opts)
        .expect("fit")
}

fn registry(addrs: Vec<String>, epoch: u64) -> RegistryConfig {
    let mut cfg = RegistryConfig::new(addrs);
    cfg.health_interval = Duration::from_millis(25);
    cfg.reconnect_backoff = Duration::from_millis(25);
    cfg.remote.timeout = TIMEOUT;
    cfg.remote.claim_epoch = Some(epoch);
    cfg
}

fn assert_bits_eq(a: &Mat, b: &Mat, what: &str) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{what}: shape mismatch");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i} differs ({x} vs {y})");
    }
}

#[test]
fn primary_death_standby_steal_and_fenced_zombie() {
    let base = std::env::temp_dir()
        .join(format!("gdkron-chaos-failover-{}.wal", std::process::id()));
    let paths = WalPaths::from_base(&base);
    let mut lease_os = base.clone().into_os_string();
    lease_os.push(".lease");
    let lease = std::path::PathBuf::from(lease_os);
    for p in [&paths.wal, &paths.snap, &lease] {
        let _ = std::fs::remove_file(p);
    }

    let addrs = vec![spawn_worker(), spawn_worker()];

    // identical fits: the (soon-to-be-sharded) primary and its unsharded
    // mirror — the oracle every later state is compared against
    let (d, n0) = (4usize, 3usize);
    let mut rng = Rng::new(71);
    let x0 = Mat::from_fn(d, n0, |_, _| rng.gauss());
    let g0 = Mat::from_fn(d, n0, |_, _| rng.gauss());
    let mut primary = fit(&x0, &g0);
    let mut mirror = fit(&x0, &g0);

    // the primary takes the lease at epoch 1, claims the workers, and
    // opens the WAL (fsync on — this is the durability path under test)
    let keeper = LeaseKeeper::acquire(&lease, "primary", TTL).expect("fresh lease");
    assert_eq!(keeper.epoch(), 1);
    primary.set_remote_registry(registry(addrs.clone(), keeper.epoch())).expect("claimed attach");
    assert_eq!(primary.shards(), 2);
    let wal_opts = WalOptions { fsync: true, snapshot_interval: 3 };
    let mut wal = WalWriter::create(paths.clone(), wal_opts, &primary, 0).expect("wal");

    // streamed serving: WAL-first, sharded solve, heartbeat — with a
    // snapshot compaction landing mid-stream so the failover also
    // exercises the snapshot + tail recovery path
    for _ in 0..5 {
        let xc: Vec<f64> = (0..d).map(|_| rng.gauss()).collect();
        let gc: Vec<f64> = (0..d).map(|_| rng.gauss()).collect();
        wal.log_observe(&xc, &gc).expect("WAL-first append");
        primary.observe(&xc, &gc).expect("primary observe");
        mirror.observe(&xc, &gc).expect("mirror observe");
        if wal.snapshot_due() {
            wal.write_snapshot(&primary).expect("snapshot compaction");
        }
        keeper.renew().expect("primary heartbeat");
    }
    assert!(primary.shard_degradation().is_none(), "fleet must be healthy pre-fault");
    assert_bits_eq(primary.gp().z(), mirror.gp().z(), "sharded primary vs unsharded mirror");

    // a hot standby tails the WAL while the primary lives...
    let mut sb = Standby::new(paths.clone(), Arc::new(SquaredExponential), fit_method());
    let r = sb.catch_up().expect("tail while the primary is alive");
    assert_eq!(r.apply_errors, 0);
    assert_eq!(sb.applied_seq(), 6, "genesis + five observes");
    // ...but must NOT be able to steal a live lease
    keeper.renew().expect("primary heartbeat");
    let held = LeaseKeeper::acquire(&lease, "standby", TTL).unwrap_err().to_string();
    assert!(held.contains("held by"), "live lease must not be stealable: {held}");

    // PRIMARY DIES: it simply stops renewing. The lease lapses after TTL.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let l = read_lease(&lease).unwrap().expect("lease file exists");
        if l.expired_at(now_unix_ms()) {
            break;
        }
        assert!(Instant::now() < deadline, "lease must lapse once renewals stop");
        thread::sleep(Duration::from_millis(20));
    }

    // STANDBY TAKES OVER: final catch-up, steal at epoch 2, claim workers
    sb.catch_up().expect("final catch-up");
    let thief = LeaseKeeper::acquire(&lease, "standby", TTL).expect("steal a lapsed lease");
    assert_eq!(thief.epoch(), 2, "the steal must fence every epoch-1 session");
    let (mut promoted, window) = sb.promote().expect("promote");
    assert_eq!(window, 0);
    promoted
        .set_remote_registry(registry(addrs.clone(), thief.epoch()))
        .expect("claimed re-attach at the stolen epoch");
    assert_eq!(promoted.shards(), 2);

    // the replayed state is bitwise the mirror's — and it got there with
    // zero cold refits beyond the initial fit
    assert_bits_eq(promoted.gp().x(), mirror.gp().x(), "X after failover");
    assert_bits_eq(promoted.gp().g(), mirror.gp().g(), "G after failover");
    assert_bits_eq(promoted.gp().z(), mirror.gp().z(), "Z after failover");
    assert_eq!(promoted.cold_refits(), 1, "failover must not cold-refit");

    // ZOMBIE: the old primary wakes up. Its heartbeat sees the steal...
    let stolen = keeper.renew().unwrap_err().to_string();
    assert!(stolen.contains("stolen"), "zombie renewal must report the steal: {stolen}");
    // ...and its streamed write is fenced at the workers: the zombie keeps
    // serving itself from the in-process fallback (no panic, no hang), but
    // the fleet state is untouched
    let xz: Vec<f64> = (0..d).map(|_| rng.gauss()).collect();
    let gz: Vec<f64> = (0..d).map(|_| rng.gauss()).collect();
    primary.observe(&xz, &gz).expect("zombie observe degrades, not errors");
    let reason = primary.shard_degradation().expect("zombie must be degraded");
    assert!(
        reason.contains("stale coordinator epoch"),
        "degradation must cite the epoch fence: {reason}"
    );

    // the new primary is unaffected by the zombie's attempt: it re-creates
    // the WAL from its promoted state and keeps streaming, and its sharded
    // solves — through the very worker mirrors the zombie tried to write —
    // stay bitwise equal to the unsharded mirror
    let mut wal2 = WalWriter::create(paths.clone(), wal_opts, &promoted, window).expect("wal2");
    for _ in 0..3 {
        let xc: Vec<f64> = (0..d).map(|_| rng.gauss()).collect();
        let gc: Vec<f64> = (0..d).map(|_| rng.gauss()).collect();
        wal2.log_observe(&xc, &gc).expect("WAL-first append");
        promoted.observe(&xc, &gc).expect("post-failover observe");
        mirror.observe(&xc, &gc).expect("mirror observe");
        thief.renew().expect("new primary heartbeat");
    }
    assert!(
        promoted.shard_degradation().is_none(),
        "the fence must not touch the epoch-2 holder"
    );
    assert_bits_eq(promoted.gp().z(), mirror.gp().z(), "Z after the zombie's fenced write");
    assert_eq!(promoted.cold_refits(), 1, "steady state must stay incremental");

    for p in [&paths.wal, &paths.snap, &lease] {
        let _ = std::fs::remove_file(p);
    }
}

/// Both tiers must survive a failover bitwise: hot window AND compacted
/// tail, field for field.
fn assert_tiers_eq(a: &OnlineGradientGp, b: &OnlineGradientGp, what: &str) {
    assert_bits_eq(a.gp().x(), b.gp().x(), &format!("{what}: X"));
    assert_bits_eq(a.gp().g(), b.gp().g(), &format!("{what}: G"));
    assert_bits_eq(a.gp().z(), b.gp().z(), &format!("{what}: Z"));
    assert_eq!(a.tail_len(), b.tail_len(), "{what}: tail length");
    assert_eq!(a.compactions(), b.compactions(), "{what}: fold count");
    if let (Some(at), Some(bt)) = (a.gp().tail(), b.gp().tail()) {
        assert_bits_eq(&at.xt, &bt.xt, &format!("{what}: tail X̃"));
        assert_bits_eq(&at.lam_xt, &bt.lam_xt, &format!("{what}: tail ΛX̃"));
        assert_bits_eq(&at.w, &bt.w, &format!("{what}: tail W"));
        assert_bits_eq(&at.at_hot, &bt.at_hot, &format!("{what}: tail at_hot"));
    }
}

#[test]
fn windowed_failover_carries_the_compacted_tail_bitwise() {
    // the tiered-posterior leg of the chaos pin: a windowed primary with
    // `gp.compaction = exact` degrades and fails over, and the promoted
    // standby carries BOTH tiers — folds replayed from the barrier
    // sequence alone, the mid-stream snapshot restoring at_hot verbatim.
    let base = std::env::temp_dir()
        .join(format!("gdkron-chaos-fold-{}.wal", std::process::id()));
    let paths = WalPaths::from_base(&base);
    let mut lease_os = base.clone().into_os_string();
    lease_os.push(".lease");
    let lease = std::path::PathBuf::from(lease_os);
    for p in [&paths.wal, &paths.snap, &lease] {
        let _ = std::fs::remove_file(p);
    }

    let addrs = vec![spawn_worker(), spawn_worker()];
    let win = 3;
    let (d, n0) = (4usize, 2usize);
    let mut rng = Rng::new(72);
    let x0 = Mat::from_fn(d, n0, |_, _| rng.gauss());
    let g0 = Mat::from_fn(d, n0, |_, _| rng.gauss());
    let mut primary = fit(&x0, &g0);
    let mut mirror = fit(&x0, &g0);
    primary.set_compaction(Compaction::Exact);
    mirror.set_compaction(Compaction::Exact);

    let keeper = LeaseKeeper::acquire(&lease, "primary", TTL).expect("fresh lease");
    primary.set_remote_registry(registry(addrs.clone(), keeper.epoch())).expect("attach");
    assert_eq!(primary.shards(), 2);
    // snapshot_interval 3: a compaction lands mid-stream, so the failover
    // also proves the snapshot serializes the tail verbatim
    let wal_opts = WalOptions { fsync: true, snapshot_interval: 3 };
    let mut wal = WalWriter::create(paths.clone(), wal_opts, &primary, win).expect("wal");

    for _ in 0..6 {
        let xc: Vec<f64> = (0..d).map(|_| rng.gauss()).collect();
        let gc: Vec<f64> = (0..d).map(|_| rng.gauss()).collect();
        wal.log_observe(&xc, &gc).expect("WAL-first append");
        primary.observe_windowed(&xc, &gc, win).expect("primary observe");
        mirror.observe_windowed(&xc, &gc, win).expect("mirror observe");
        if wal.snapshot_due() {
            wal.write_snapshot(&primary).expect("snapshot compaction");
        }
        keeper.renew().expect("primary heartbeat");
    }
    assert_eq!(primary.n(), win, "window must be saturated");
    assert_eq!(primary.tail_len(), 5, "five evictions must have folded");
    assert!(primary.shard_degradation().is_none(), "fleet must be healthy pre-fault");
    assert_tiers_eq(&primary, &mirror, "sharded primary vs unsharded mirror");

    // PRIMARY DIES; the lease lapses
    drop(keeper);
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let l = read_lease(&lease).unwrap().expect("lease file exists");
        if l.expired_at(now_unix_ms()) {
            break;
        }
        assert!(Instant::now() < deadline, "lease must lapse once renewals stop");
        thread::sleep(Duration::from_millis(20));
    }

    // STANDBY TAKES OVER with both tiers intact
    let mut sb = Standby::new(paths.clone(), Arc::new(SquaredExponential), fit_method());
    let r = sb.catch_up().expect("catch-up");
    assert_eq!(r.apply_errors, 0);
    let thief = LeaseKeeper::acquire(&lease, "standby", TTL).expect("steal a lapsed lease");
    assert_eq!(thief.epoch(), 2);
    let (mut promoted, window) = sb.promote().expect("promote");
    assert_eq!(window, win, "genesis must carry the window boundary");
    assert_eq!(promoted.compaction(), Compaction::Exact, "genesis must carry the policy");
    promoted
        .set_remote_registry(registry(addrs, thief.epoch()))
        .expect("claimed re-attach at the stolen epoch");
    assert_tiers_eq(&promoted, &mirror, "promoted standby");
    assert_eq!(promoted.cold_refits(), 1, "failover must not cold-refit");

    // and the new primary keeps folding: the tail stays bitwise through
    // post-failover windowed serving
    for _ in 0..2 {
        let xc: Vec<f64> = (0..d).map(|_| rng.gauss()).collect();
        let gc: Vec<f64> = (0..d).map(|_| rng.gauss()).collect();
        promoted.observe_windowed(&xc, &gc, win).expect("post-failover observe");
        mirror.observe_windowed(&xc, &gc, win).expect("mirror observe");
        thief.renew().expect("new primary heartbeat");
    }
    assert_eq!(promoted.tail_len(), 7);
    assert_tiers_eq(&promoted, &mirror, "post-failover folds");

    for p in [&paths.wal, &paths.snap, &lease] {
        let _ = std::fs::remove_file(p);
    }
}
