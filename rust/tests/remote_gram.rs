//! Integration suite for the cross-node shard transport (`gram::remote`).
//!
//! Pins the PR-level acceptance criteria:
//! * **bit-identity**: loopback-TCP remote shards equal the in-process
//!   sharded path — and hence the single-shard [`GramOperator`] — *exactly*
//!   (zero ulps), across shard counts {1, 2, 3}, for SE / Matérn-5/2 /
//!   poly(2) kernels, including after online `append`/`drop_first`
//!   sequences (the `O(N + D)` wire deltas must grow the worker mirrors to
//!   the same bits as the coordinator panels);
//! * **failure is an error, never a hang**: a worker killed mid-
//!   `apply_block` surfaces as a clean `anyhow` error within the frame
//!   timeout, a version mismatch / short frame / dead address is a clean
//!   error, and after any failure the coordinator keeps serving from the
//!   in-process single-shard fallback (still bit-identical);
//! * the serving path survives remote loss: a streamed observe whose CG
//!   re-solve hits a dead worker falls back to one cold refit and keeps
//!   the posterior exact.
//!
//! Every socket operation in this suite is bounded by a short timeout, so
//! a transport regression fails the test quickly instead of wedging CI.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use gdkron::config::Config;
use gdkron::coordinator::NativeEngine;
use gdkron::gp::{FitMethod, FitOptions, GradientGp, OnlineGradientGp};
use gdkron::gram::remote::serve;
use gdkron::gram::wire::{CoordFrame, WorkerFrame, WIRE_MAGIC, WIRE_VERSION};
use gdkron::gram::{GramFactors, GramOperator, Metric, ShardedGramFactors};
use gdkron::kernels::{Matern52, Poly2Kernel, ScalarKernel, SquaredExponential};
use gdkron::linalg::Mat;
use gdkron::rng::Rng;
use gdkron::solvers::{CgOptions, LinearOp};

/// Frame timeout for every endpoint in this suite: long enough for a slow
/// CI box, short enough that a wedged transport fails the test fast.
const TIMEOUT: Duration = Duration::from_secs(5);

/// An upper bound on "fails fast": generous against CI jitter, far below
/// anything a human would call a hang.
const FAIL_FAST: Duration = Duration::from_secs(60);

fn sample(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.gauss())
}

/// Spawn a real `gdkron shard-worker` on an ephemeral loopback port.
fn spawn_worker() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let _ = serve(listener);
    });
    addr
}

fn spawn_workers(s: usize) -> Vec<String> {
    (0..s).map(|_| spawn_worker()).collect()
}

/// Fault-injection worker behaviors.
enum Fault {
    /// Handshake and state frames are fine; the connection is dropped the
    /// moment an `Apply` frame arrives — the mid-apply kill.
    DieOnApply,
    /// Answers the handshake with the wrong protocol version.
    WrongVersion,
    /// Answers the first `Apply` with a frame whose header lies about its
    /// payload length, then closes — the short-frame corruption.
    ShortFrameOnApply,
}

/// A wire-speaking fake worker exercising one failure mode.
fn spawn_faulty_worker(fault: Fault) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let (mut stream, _) = match listener.accept() {
            Ok(c) => c,
            Err(_) => return,
        };
        match CoordFrame::read_from(&mut stream) {
            Ok(CoordFrame::Hello { .. }) => {}
            _ => return,
        }
        let version = match fault {
            Fault::WrongVersion => WIRE_VERSION + 1,
            _ => WIRE_VERSION,
        };
        if (WorkerFrame::HelloAck { version }).write_to(&mut stream).is_err() {
            return;
        }
        if matches!(fault, Fault::WrongVersion) {
            return;
        }
        loop {
            match CoordFrame::read_opt(&mut stream) {
                Ok(Some(CoordFrame::Apply { .. })) => match fault {
                    Fault::DieOnApply => return, // connection dropped mid-apply
                    Fault::ShortFrameOnApply => {
                        use std::io::Write;
                        // header claims 64 payload bytes, ships 3, closes
                        let mut bad = Vec::new();
                        bad.extend_from_slice(&64u32.to_le_bytes());
                        bad.push(0x83); // Diag tag
                        bad.extend_from_slice(&[1, 2, 3]);
                        let _ = stream.write_all(&bad);
                        return;
                    }
                    Fault::WrongVersion => unreachable!(),
                },
                // consume Sync / Append / DropFirst silently
                Ok(Some(_)) => continue,
                Ok(None) | Err(_) => return,
            }
        }
    });
    addr
}

/// The kernel/metric/center matrix the bit-identity sweep covers.
fn cases() -> Vec<(Box<dyn ScalarKernel>, Metric, Option<Vec<f64>>, &'static str)> {
    let d = 6;
    let c: Vec<f64> = (0..d).map(|i| 0.1 * (i as f64) - 0.2).collect();
    vec![
        (Box::new(SquaredExponential), Metric::Iso(0.6), None, "se-iso"),
        (Box::new(Matern52), Metric::Iso(0.8), None, "matern52"),
        (Box::new(Poly2Kernel), Metric::Iso(0.9), Some(c), "poly2"),
    ]
}

fn assert_factors_bitwise(a: &GramFactors, b: &GramFactors, what: &str) {
    assert_eq!(a.n(), b.n(), "{what}: N");
    for (pa, pb, name) in [
        (&a.xt, &b.xt, "xt"),
        (&a.lam_xt, &b.lam_xt, "lam_xt"),
        (&a.lam_xt_t, &b.lam_xt_t, "lam_xt_t"),
        (&a.r, &b.r, "r"),
        (&a.h, &b.h, "h"),
        (&a.kp_eff, &b.kp_eff, "kp_eff"),
        (&a.kpp_eff, &b.kpp_eff, "kpp_eff"),
    ] {
        assert!((pa - pb).max_abs() == 0.0, "{what}: panel {name} diverged");
    }
}

#[test]
fn loopback_remote_bit_identical_across_shard_counts_kernels_and_deltas() {
    for (kern, metric, center, label) in cases() {
        let x = sample(6, 8, 21);
        let seed_x = x.block(0, 0, 6, 3);
        // serial reference: the same append ×3 / drop ×2 / append ×2 deltas
        let serial = {
            let mut f =
                GramFactors::new(kern.as_ref(), &seed_x, metric.clone(), center.as_deref());
            for j in 3..6 {
                f.append(kern.as_ref(), x.col(j));
            }
            f.drop_first();
            f.drop_first();
            for j in 6..8 {
                f.append(kern.as_ref(), x.col(j));
            }
            f
        };
        for s in [1usize, 2, 3] {
            let addrs = spawn_workers(s);
            let mut f =
                GramFactors::new(kern.as_ref(), &seed_x, metric.clone(), center.as_deref());
            let mut engine =
                ShardedGramFactors::connect_remote(&f, &addrs, TIMEOUT).expect("connect");
            assert!(engine.is_remote());
            assert_eq!(engine.shards(), s);
            for j in 3..6 {
                engine.append(&mut f, kern.as_ref(), x.col(j));
            }
            engine.drop_first(&mut f);
            engine.drop_first(&mut f);
            for j in 6..8 {
                engine.append(&mut f, kern.as_ref(), x.col(j));
            }
            assert!(
                engine.degraded_reason().is_none(),
                "{label} S={s}: transport degraded: {:?}",
                engine.degraded_reason()
            );
            assert_factors_bitwise(&f, &serial, &format!("{label} S={s}"));

            let nd = f.n() * f.d();
            let stacked = sample(nd, 3, 22);
            let mut want = Mat::zeros(nd, 3);
            GramOperator::new(&serial).apply_block(&stacked, &mut want);
            let mut got = Mat::zeros(nd, 3);
            engine.apply_block_into(&stacked, &mut got).expect("remote apply");
            assert!(
                (&got - &want).max_abs() == 0.0,
                "{label} S={s}: remote apply_block is not bit-identical"
            );

            // the single-vector LinearOp surface too
            let op = engine.operator();
            let mut y = vec![0.0; nd];
            op.apply(stacked.col(0), &mut y);
            let mut yref = vec![0.0; nd];
            GramOperator::new(&serial).apply(stacked.col(0), &mut yref);
            assert_eq!(y, yref, "{label} S={s}: apply must be bit-identical");
        }
    }
}

#[test]
fn online_streaming_remote_matches_in_process_bitwise() {
    // the full serving stack: streamed observes + window slides through
    // the iterative engine, remote-TCP shards vs in-process shards —
    // identical to the last bit
    let (d, w) = (6, 5);
    let x = sample(d, w + 4, 51);
    let g = sample(d, w + 4, 52);
    let opts = FitOptions {
        method: FitMethod::Iterative(CgOptions {
            rtol: 1e-10,
            max_iters: 20_000,
            ..Default::default()
        }),
        ..Default::default()
    };
    let run = |remote: Option<Vec<String>>| {
        let mut online = OnlineGradientGp::fit(
            Arc::new(SquaredExponential),
            Metric::Iso(0.5),
            &x.block(0, 0, d, w),
            &g.block(0, 0, d, w),
            &opts,
        )
        .expect("fit");
        match remote {
            Some(addrs) => online.set_remote_shards(&addrs, TIMEOUT).expect("connect"),
            None => online.set_shards(2),
        }
        for j in w..w + 4 {
            online.observe(x.col(j), g.col(j)).expect("observe");
            online.drop_first().expect("drop");
        }
        assert_eq!(online.cold_refits(), 1, "steady state must not cold-refit");
        online
    };
    let local = run(None);
    let remote = run(Some(spawn_workers(2)));
    assert!(remote.shard_degradation().is_none());
    assert!(
        (local.gp().z() - remote.gp().z()).max_abs() == 0.0,
        "remote representer weights must be bit-identical to in-process sharding"
    );
    let xq = sample(d, 1, 53);
    assert_eq!(
        local.gp().predict_gradient(xq.col(0)),
        remote.gp().predict_gradient(xq.col(0)),
        "remote predictions must be bit-identical"
    );
}

#[test]
fn mid_apply_disconnect_is_a_clean_error_then_falls_back() {
    let x = sample(5, 4, 31);
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(0.5), None);
    let addr = spawn_faulty_worker(Fault::DieOnApply);
    let engine =
        ShardedGramFactors::connect_remote(&f, &[addr], Duration::from_secs(2)).expect("connect");
    let nd = f.n() * f.d();
    let xin = sample(nd, 2, 32);
    let mut y = Mat::zeros(nd, 2);
    let t0 = Instant::now();
    let err = engine.apply_block_into(&xin, &mut y).unwrap_err().to_string();
    assert!(
        t0.elapsed() < FAIL_FAST,
        "mid-apply disconnect must error within the frame timeout, not hang"
    );
    assert!(err.contains("fallback"), "error should announce the degradation: {err}");
    assert!(engine.is_degraded());
    // … and the engine keeps serving from the in-process single-shard
    // fallback, still bit-identically
    let mut got = Mat::zeros(nd, 2);
    engine.apply_block_into(&xin, &mut got).expect("fallback apply");
    let mut want = Mat::zeros(nd, 2);
    GramOperator::new(&f).apply_block(&xin, &mut want);
    assert!((&got - &want).max_abs() == 0.0, "fallback must stay bit-identical");
}

#[test]
fn solve_path_surfaces_remote_loss_and_recovers_via_cold_refit() {
    // the serving contract end-to-end: a worker dying mid-apply during the
    // CG re-solve is a clean error inside the update machinery, the update
    // falls back to one cold refit, and the posterior stays exact
    let (d, n) = (5, 4);
    let x = sample(d, n + 1, 61);
    let g = sample(d, n + 1, 62);
    let opts = FitOptions {
        method: FitMethod::Iterative(CgOptions {
            rtol: 1e-10,
            max_iters: 20_000,
            ..Default::default()
        }),
        ..Default::default()
    };
    let mut online = OnlineGradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::Iso(0.5),
        &x.block(0, 0, d, n),
        &g.block(0, 0, d, n),
        &opts,
    )
    .expect("fit");
    online
        .set_remote_shards(&[spawn_faulty_worker(Fault::DieOnApply)], Duration::from_secs(2))
        .expect("connect");
    // a pure re-target re-solves through the (dying) remote operator
    let g2 = sample(d, n, 63);
    let t0 = Instant::now();
    online.set_targets(&g2).expect("set_targets must recover via cold refit");
    assert!(t0.elapsed() < FAIL_FAST, "remote loss must not stall the update");
    assert_eq!(online.cold_refits(), 2, "exactly one recovery cold refit");
    assert!(online.shard_degradation().is_some(), "degradation must be visible");
    // further streamed updates ride the in-process fallback
    online.observe(x.col(n), g.col(n)).expect("observe after degradation");
    assert_eq!(online.cold_refits(), 2, "fallback serving needs no further refits");
    // the posterior equals a cold model on the same final window
    let mut xx = x.block(0, 0, d, n);
    xx.push_col(x.col(n));
    let mut gx = g2.clone();
    gx.push_col(g.col(n));
    let cold = GradientGp::fit(Arc::new(SquaredExponential), Metric::Iso(0.5), &xx, &gx, &opts)
        .expect("cold fit");
    let xq: Vec<f64> = (0..d).map(|i| 0.3 - 0.1 * i as f64).collect();
    let po = online.gp().predict_gradient(&xq);
    let pc = cold.predict_gradient(&xq);
    for i in 0..d {
        assert!(
            (po[i] - pc[i]).abs() < 1e-8 * (1.0 + pc[i].abs()),
            "dim {i}: {} vs {}",
            po[i],
            pc[i]
        );
    }
}

#[test]
fn version_mismatch_is_a_clean_connect_error() {
    let x = sample(4, 3, 41);
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(0.5), None);
    let addr = spawn_faulty_worker(Fault::WrongVersion);
    let err = ShardedGramFactors::connect_remote(&f, &[addr], Duration::from_secs(2))
        .unwrap_err()
        .to_string();
    assert!(err.contains("version"), "error should name the version mismatch: {err}");
}

#[test]
fn short_frame_mid_apply_is_a_clean_error() {
    let x = sample(5, 4, 42);
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(0.5), None);
    let addr = spawn_faulty_worker(Fault::ShortFrameOnApply);
    let engine =
        ShardedGramFactors::connect_remote(&f, &[addr], Duration::from_secs(2)).expect("connect");
    let nd = f.n() * f.d();
    let xin = sample(nd, 1, 43);
    let mut y = Mat::zeros(nd, 1);
    let t0 = Instant::now();
    let err = engine.apply_block_into(&xin, &mut y).unwrap_err().to_string();
    assert!(t0.elapsed() < FAIL_FAST, "a short frame must not hang the reader");
    assert!(
        err.contains("mid-frame") || err.contains("short frame"),
        "error should name the framing problem: {err}"
    );
    assert!(engine.is_degraded());
}

#[test]
fn connect_to_dead_address_fails_fast() {
    // bind-then-drop: the port is closed, the connect must be refused (or
    // time out) promptly — startup never hangs on a dead worker
    let dead = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let x = sample(4, 3, 44);
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(0.5), None);
    let t0 = Instant::now();
    let res = ShardedGramFactors::connect_remote(&f, &[dead], Duration::from_secs(2));
    assert!(res.is_err(), "a dead address must be a connect error");
    assert!(t0.elapsed() < FAIL_FAST, "the connect error must arrive promptly");
}

#[test]
fn from_config_falls_back_cleanly_when_remote_unavailable() {
    // NativeEngine::from_config with an unreachable remote list must log,
    // fall back to the in-process shard knob, and keep serving
    let dead = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let mut rng = Rng::new(71);
    let x = Mat::from_fn(4, 3, |_, _| rng.gauss());
    let g = Mat::from_fn(4, 3, |_, _| rng.gauss());
    let gp = GradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::Iso(0.5),
        &x,
        &g,
        &FitOptions::default(),
    )
    .expect("fit");
    let expected = gp.predict_gradient(x.col(0));
    let cfg = Config::from_str(&format!(
        "[gram]\nremote_shards = [\"{dead}\"]\nremote_timeout_ms = 500\nshards = 2\n"
    ))
    .unwrap();
    let engine = NativeEngine::from_config(gp, &cfg);
    assert_eq!(engine.shards(), 2, "must fall back to the in-process shard knob");
    assert_eq!(engine.gp().predict_gradient(x.col(0)), expected, "and keep serving");
}

#[test]
fn worker_serves_successive_coordinators() {
    // one long-lived worker, two serving sessions: detaching the first
    // coordinator (drop → Shutdown frame) must leave the worker ready to
    // host the next
    let addr = spawn_worker();
    let x = sample(4, 5, 81);
    let f = GramFactors::new(&SquaredExponential, &x, Metric::Iso(0.6), None);
    let nd = f.n() * f.d();
    let xin = sample(nd, 1, 82);
    let mut want = Mat::zeros(nd, 1);
    GramOperator::new(&f).apply_block(&xin, &mut want);
    for round in 0..2 {
        let engine = ShardedGramFactors::connect_remote(&f, &[addr.clone()], TIMEOUT)
            .unwrap_or_else(|e| panic!("round {round}: connect failed: {e}"));
        let mut got = Mat::zeros(nd, 1);
        engine.apply_block_into(&xin, &mut got).expect("apply");
        assert!((&got - &want).max_abs() == 0.0, "round {round}: not bit-identical");
        drop(engine);
    }
}

#[test]
fn real_worker_rejects_too_old_coordinator_with_err_frame() {
    // below MIN_WIRE_VERSION there is nothing to negotiate down to: the
    // worker must answer with a descriptive Err frame, never a misparse
    let addr = spawn_worker();
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(TIMEOUT)).unwrap();
    CoordFrame::Hello { magic: WIRE_MAGIC, version: 0 }.write_to(&mut stream).unwrap();
    match WorkerFrame::read_from(&mut stream).unwrap() {
        WorkerFrame::Err { message } => {
            assert!(message.contains("version"), "unexpected error: {message}")
        }
        _ => panic!("expected an Err frame for the version mismatch"),
    }
}

#[test]
fn real_worker_negotiates_down_for_old_and_new_coordinators() {
    // a v1 coordinator is still served (HelloAck v1), and a coordinator
    // NEWER than the worker negotiates down to the worker's version — the
    // backward-compatible Hello of the v2 protocol
    let addr = spawn_worker();
    for hello in [1u16, WIRE_VERSION + 1] {
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.set_read_timeout(Some(TIMEOUT)).unwrap();
        CoordFrame::Hello { magic: WIRE_MAGIC, version: hello }.write_to(&mut stream).unwrap();
        match WorkerFrame::read_from(&mut stream).unwrap() {
            WorkerFrame::HelloAck { version } => {
                assert_eq!(
                    version,
                    hello.min(WIRE_VERSION),
                    "HelloAck must carry the negotiated (min) version"
                );
            }
            _ => panic!("expected HelloAck for Hello v{hello}"),
        }
    }
}

#[test]
fn real_worker_rejects_apply_before_sync_with_err_frame() {
    let addr = spawn_worker();
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(TIMEOUT)).unwrap();
    CoordFrame::Hello { magic: WIRE_MAGIC, version: WIRE_VERSION }.write_to(&mut stream).unwrap();
    match WorkerFrame::read_from(&mut stream).unwrap() {
        WorkerFrame::HelloAck { version } => assert_eq!(version, WIRE_VERSION),
        _ => panic!("expected HelloAck"),
    }
    CoordFrame::Apply { xin: Mat::zeros(4, 1) }.write_to(&mut stream).unwrap();
    match WorkerFrame::read_from(&mut stream).unwrap() {
        WorkerFrame::Err { message } => {
            assert!(message.contains("before sync"), "unexpected error: {message}")
        }
        _ => panic!("expected an Err frame for the unsynced apply"),
    }
}
