//! In-tree shim of the `anyhow` error API.
//!
//! Substitution note (DESIGN.md §6): the build environment has no network
//! registry, so this workspace member stands in for the real crate under the
//! same name. It implements exactly the subset the `gdkron` sources use —
//! [`Error`], [`Result`], [`anyhow!`], [`ensure!`], [`bail!`] and the
//! [`Context`] extension trait — with the same semantics (a type-erased,
//! `Send + Sync` error carrying a message chain, a blanket `From` for
//! standard errors so `?` works on io/parse errors).
//!
//! Context chains follow the real crate's display convention: `{}` shows
//! only the **outermost** message, `{:#}` joins the whole chain outermost →
//! root cause with `": "`. Anything that forwards an error across a process
//! or channel boundary as text must therefore format it with `{:#}` (or
//! [`Error::root_cause`] stays unreachable on the far side).
//!
//! Deliberately *not* implemented: backtraces and downcasting. Code that
//! needs those should extend this shim rather than work around it.

use std::fmt;

/// Type-erased error: a display message plus an optional source chain (the
/// only things the workspace ever reads back out of an `anyhow::Error`).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build from anything displayable — the workhorse behind [`anyhow!`].
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap `self` in an outer context message. `{}` then displays only
    /// `message`; `{:#}` displays `message: …: root cause`.
    pub fn context<M: fmt::Display>(self, message: M) -> Self {
        Error { msg: message.to_string(), source: Some(Box::new(self)) }
    }

    /// The innermost message of the chain (the original failure).
    pub fn root_cause(&self) -> &str {
        let mut e = self;
        while let Some(src) = e.source.as_deref() {
            e = src;
        }
        &e.msg
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let e = next?;
            next = e.source.as_deref();
            Some(e.msg.as_str())
        })
    }

    fn fmt_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut src = self.source.as_deref();
        while let Some(e) = src {
            write!(f, ": {}", e.msg)?;
            src = e.source.as_deref();
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.fmt_chain(f)
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `unwrap()` panics and `{:?}` logs must show the whole story
        self.fmt_chain(f)
    }
}

/// `?`-conversion from any standard error. Mirrors the real crate: `Error`
/// itself does not implement `std::error::Error`, which is what keeps this
/// blanket impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as the default
/// error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(…)` / `.with_context(…)` to
/// `Result<T, anyhow::Error>` and `Option<T>` (the two shapes the workspace
/// chains on; convert std errors with `?` first).
pub trait Context<T> {
    /// Wrap the error (or `None`) in an outer context message.
    fn context<M: fmt::Display>(self, message: M) -> Result<T>;
    /// Lazily-built variant: `f` runs only on the error path.
    fn with_context<M: fmt::Display, F: FnOnce() -> M>(self, f: F) -> Result<T>;
}

impl<T> Context<T> for Result<T, Error> {
    fn context<M: fmt::Display>(self, message: M) -> Result<T> {
        self.map_err(|e| e.context(message))
    }
    fn with_context<M: fmt::Display, F: FnOnce() -> M>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<M: fmt::Display>(self, message: M) -> Result<T> {
        self.ok_or_else(|| Error::msg(message))
    }
    fn with_context<M: fmt::Display, F: FnOnce() -> M>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    #[allow(unused_imports)]
    use super::*;

    fn formats(x: i32) -> Result<()> {
        ensure!(x > 0, "x must be positive, got {x}");
        if x > 100 {
            bail!("x too big: {}", x);
        }
        Ok(())
    }

    #[test]
    fn macro_forms() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let v = 3;
        let e = anyhow!("value {v}");
        assert_eq!(e.to_string(), "value 3");
        let e = anyhow!("value {}", 4);
        assert_eq!(e.to_string(), "value 4");
        assert!(formats(5).is_ok());
        assert_eq!(formats(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(formats(101).unwrap_err().to_string(), "x too big: 101");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }

    #[test]
    fn context_chain_display_and_alternate() {
        let root: Result<()> = Err(anyhow!("connection reset"));
        let e = root.context("apply failed").context("solve aborted").unwrap_err();
        // `{}` = outermost only (real-anyhow convention) …
        assert_eq!(e.to_string(), "solve aborted");
        // … `{:#}` = the full chain, outermost → root cause
        assert_eq!(format!("{e:#}"), "solve aborted: apply failed: connection reset");
        assert_eq!(format!("{e:?}"), "solve aborted: apply failed: connection reset");
        assert_eq!(e.root_cause(), "connection reset");
        let parts: Vec<&str> = e.chain().collect();
        assert_eq!(parts, vec!["solve aborted", "apply failed", "connection reset"]);
    }

    #[test]
    fn with_context_is_lazy_and_option_context_works() {
        let ok: Result<i32> = Ok(7);
        let ok = ok.with_context(|| -> String { unreachable!("must not run on Ok") });
        assert_eq!(ok.unwrap(), 7);
        let none: Option<i32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }
}
