//! In-tree shim of the `anyhow` error API.
//!
//! Substitution note (DESIGN.md §6): the build environment has no network
//! registry, so this workspace member stands in for the real crate under the
//! same name. It implements exactly the subset the `gdkron` sources use —
//! [`Error`], [`Result`], [`anyhow!`], [`ensure!`] and [`bail!`] — with the
//! same semantics (a type-erased, `Send + Sync` error carrying a message, a
//! blanket `From` for standard errors so `?` works on io/parse errors).
//!
//! Deliberately *not* implemented: `Context`/`with_context`, backtraces and
//! downcasting. Code that needs those should extend this shim rather than
//! work around it.

use std::fmt;

/// Type-erased error: a display message (the only thing the workspace ever
/// reads back out of an `anyhow::Error`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from anything displayable — the workhorse behind [`anyhow!`].
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// `?`-conversion from any standard error. Mirrors the real crate: `Error`
/// itself does not implement `std::error::Error`, which is what keeps this
/// blanket impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as the default
/// error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    #[allow(unused_imports)]
    use super::*;

    fn formats(x: i32) -> Result<()> {
        ensure!(x > 0, "x must be positive, got {x}");
        if x > 100 {
            bail!("x too big: {}", x);
        }
        Ok(())
    }

    #[test]
    fn macro_forms() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let v = 3;
        let e = anyhow!("value {v}");
        assert_eq!(e.to_string(), "value 3");
        let e = anyhow!("value {}", 4);
        assert_eq!(e.to_string(), "value 4");
        assert!(formats(5).is_ok());
        assert_eq!(formats(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(formats(101).unwrap_err().to_string(), "x too big: 101");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
