//! Probabilistic linear algebra (Sec. 4.2): solve `Ax = b` with the poly(2)
//! gradient GP at `O(N²D + N³)` per iteration, vs conjugate gradients.
//!
//! ```bash
//! cargo run --release --example linear_solver
//! ```

use gdkron::opt::{plinalg, LinearCg, Quadratic};
use gdkron::rng::Rng;

fn main() {
    let d = 100;
    let mut rng = Rng::new(3);
    let (q, x0) = Quadratic::paper_f1(d, 0.5, 100.0, 0.6, &mut rng);
    println!("solving a {d}-dimensional SPD system (κ = 200, App. F.1 spectrum)\n");

    let cg = LinearCg { gtol: 1e-5, max_iters: 300 }.minimize(&q, &x0);
    println!(
        "CG                    : {:>3} iterations, final ‖g‖ = {:.2e}",
        cg.iterations(),
        cg.gnorm.last().unwrap()
    );

    let ss = plinalg::solution_solver(&q, &x0, 1e-5, 300);
    println!(
        "GP-X (solution-based) : {:>3} iterations, final ‖g‖ = {:.2e}",
        ss.iterations(),
        ss.gnorm.last().unwrap()
    );

    let hs = plinalg::hessian_solver(&q, &x0, 1e-5, 300);
    println!(
        "GP-H (Hessian, c = 0) : {:>3} iterations, final ‖g‖ = {:.2e}  (paper: \"compromised\")",
        hs.iterations(),
        hs.gnorm.last().unwrap()
    );

    // solution quality of the probabilistic solver
    let err: f64 = ss
        .x
        .iter()
        .zip(&q.xstar)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    println!("\nGP-X solution error ‖x − x⋆‖ = {err:.2e}");
}
