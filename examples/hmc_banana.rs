//! GPG-HMC (Sec. 5.3): sample a 100-D banana density with a GP gradient
//! surrogate trained on only ⌊√D⌋ = 10 true gradient evaluations.
//!
//! ```bash
//! cargo run --release --example hmc_banana
//! ```

use gdkron::hmc::{diagnostics, run_gpg_hmc, run_hmc, Banana, GpgConfig, Target, TrueGradient};
use gdkron::rng::Rng;

fn main() -> anyhow::Result<()> {
    let d = 100;
    let n_samples = 500;
    let target = Banana::new(d);
    let cfg = GpgConfig::paper_defaults(d, 0.004);
    let mut rng = Rng::new(7);
    let x0 = rng.gauss_vec(d);

    // plain HMC baseline
    let mut tg = TrueGradient::new(&target);
    let hmc = run_hmc(&target, &mut tg, &x0, n_samples, &cfg.hmc, &mut rng);
    println!(
        "HMC    : accept {:.2}, {} true-gradient evaluations",
        hmc.accept_rate, hmc.true_grad_evals
    );

    // GPG-HMC: surrogate gradients after a tiny training budget
    let gpg = run_gpg_hmc(&target, &x0, n_samples, &cfg, &mut rng)?;
    println!(
        "GPG-HMC: accept {:.2}, {} true-gradient evaluations ({} training iters, {} points)",
        gpg.run.accept_rate,
        gpg.run.true_grad_evals,
        gpg.training_iters,
        gpg.train_x.cols()
    );
    println!(
        "→ {:.0}× fewer true-gradient calls overall (GPG's count is almost \
         entirely its training phase; the sampling phase uses none)",
        hmc.true_grad_evals as f64 / gpg.run.true_grad_evals.max(1) as f64
    );

    // quick sanity on the samples: tail coordinates are N(0, ½)
    let var = diagnostics::sample_var(&gpg.run.samples);
    let tail_var = var[10..].iter().sum::<f64>() / (d - 10) as f64;
    println!("mean tail-coordinate variance: {tail_var:.3} (target ≈ 0.5)");

    // energy of retained samples should be finite and reasonable
    let mut worst: f64 = 0.0;
    for j in 0..gpg.run.samples.cols() {
        let e = target.energy(gpg.run.samples.col(j));
        worst = worst.max(e);
    }
    println!("max energy among samples: {worst:.1}");
    Ok(())
}
