//! The L3 coordinator end-to-end: a batched gradient-surrogate service
//! feeding several concurrent HMC chains, with the PJRT (AOT JAX/Pallas)
//! backend when artifacts are available and the native engine otherwise.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_gradients
//! # optional server config: [server] max_batch / deadline_us /
//! #                         executors / max_queue, [runtime] threads
//! cargo run --release --example serve_gradients -- server.toml
//! ```

use std::sync::Arc;

use gdkron::config::Config;
use gdkron::coordinator::{
    BatchPolicy, Engine, NativeEngine, PjrtEngine, SchedulerOptions, SurrogateServer,
};
use gdkron::gp::{FitOptions, GradientGp};
use gdkron::gram::Metric;
use gdkron::hmc::{run_hmc, Banana, HmcConfig, Target};
use gdkron::kernels::SquaredExponential;
use gdkron::linalg::Mat;
use gdkron::rng::Rng;
use gdkron::runtime::ArtifactRegistry;

fn main() -> anyhow::Result<()> {
    // batching + threads knobs from an optional config file argument
    let config_path = std::env::args().nth(1);
    let config = match &config_path {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    let threads = gdkron::config::resolve_threads(&config);
    if threads >= 1 {
        gdkron::linalg::par::set_threads(threads);
    }

    let d = 100;
    let n_train = 10;
    let inv_l2 = 1.0 / (0.4 * d as f64);
    let target = Banana::new(d);

    // training set: 10 spread-out gradient observations (as GPG-HMC would pick)
    let mut rng = Rng::new(11);
    let mut x = Mat::zeros(d, n_train);
    let mut g = Mat::zeros(d, n_train);
    for j in 0..n_train {
        let xj = rng.uniform_vec(d, -2.0, 2.0);
        let gj = target.grad_energy(&xj);
        x.set_col(j, &xj);
        g.set_col(j, &gj);
    }
    let gp = GradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::Iso(inv_l2),
        &x,
        &g,
        &FitOptions::default(),
    )?;
    let z = gp.z().clone();

    // engine: PJRT artifact when available, native engine otherwise. The
    // cfg! gate matters: without the `pjrt` feature the registry still
    // parses manifests but cannot execute, so artifacts on disk must not
    // pull us off the native engine.
    // Config file given → its [server] keys; bare run → the historical pin.
    let policy = if config_path.is_some() {
        BatchPolicy::from_config(&config)
    } else {
        BatchPolicy { max_batch: 8, deadline: std::time::Duration::from_micros(500) }
    };
    // serving-core knobs: [server] executors (shared-engine pool width,
    // native path only — PJRT engines are thread-affine) and max_queue
    // (admission bound; overload is a fast error, not unbounded memory)
    let sched = SchedulerOptions::from_config(&config);
    let use_pjrt = cfg!(feature = "pjrt")
        && ArtifactRegistry::open("artifacts")
            .map(|r| r.spec("predict_d100_n10_b8").is_some())
            .unwrap_or(false);
    let server = if use_pjrt {
        println!("serving through the AOT PJRT artifact `predict_d100_n10_b8`");
        let xc = x.clone();
        SurrogateServer::spawn_opts(
            move || {
                let reg = ArtifactRegistry::open("artifacts")?;
                let e = PjrtEngine::new(reg, "predict_d100_n10_b8", xc, z, inv_l2)?;
                Ok(Box::new(e) as Box<dyn Engine>)
            },
            policy,
            sched,
        )?
    } else {
        println!("(PJRT artifacts unavailable — serving with the native engine)");
        // [gp] online / window keys control the engine's streaming behaviour
        let engine_cfg = config.clone();
        if sched.executors > 1 {
            println!("executor pool: {} threads over the shared native engine", sched.executors);
        }
        SurrogateServer::spawn_shared(
            move || {
                Ok(Box::new(NativeEngine::from_config(gp, &engine_cfg))
                    as Box<dyn Engine + Send + Sync>)
            },
            policy,
            sched,
        )?
    };

    // stream a few fresh observations into the live service: the native
    // engine conditions incrementally (no refit), so the serving state keeps
    // learning while it serves.
    if !use_pjrt {
        let scout = server.client();
        for _ in 0..3 {
            let xj = rng.uniform_vec(d, -2.0, 2.0);
            let gj = target.grad_energy(&xj);
            scout.observe(&xj, &gj)?;
        }
        println!("streamed 3 observations into the live surrogate (N = {})", n_train + 3);
    }

    // four concurrent HMC chains share the surrogate service
    let chains = 4;
    let samples = 100;
    let cfg = HmcConfig::paper_scaled(d, 0.004);
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..chains {
        let mut client = server.client();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            let target = Banana::new(d);
            let mut rng = Rng::new(1000 + c as u64);
            let x0 = rng.gauss_vec(d);
            let run = run_hmc(&target, &mut client, &x0, samples, &cfg, &mut rng);
            (c, run.accept_rate)
        }));
    }
    for h in handles {
        let (c, rate) = h.join().unwrap();
        println!("chain {c}: accept rate {rate:.2}");
    }
    let wall = t0.elapsed();
    let m = server.shutdown();
    println!(
        "\nserved {} gradient requests in {} batches (mean batch {:.1}, max {}) in {wall:.2?}; \
         {:.0} req/s; errors: {}",
        m.requests,
        m.batches,
        m.mean_batch(),
        m.max_batch,
        m.requests as f64 / wall.as_secs_f64(),
        m.errors
    );
    println!(
        "predict latency p50/p99/p999 ≤ {}/{}/{} µs (max {} µs); queue depth max {}; \
         rejected {}",
        m.predict_latency.p50_us(),
        m.predict_latency.p99_us(),
        m.predict_latency.p999_us(),
        m.predict_latency.max_us(),
        m.queue_depth_max,
        m.rejected
    );
    Ok(())
}
