//! Quickstart: condition a GP on gradients in D = 500 dimensions and query
//! the posterior — the thing the paper makes affordable.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;
use std::time::Instant;

use gdkron::gp::{FitOptions, GradientGp};
use gdkron::gram::Metric;
use gdkron::kernels::SquaredExponential;
use gdkron::linalg::Mat;
use gdkron::rng::Rng;

fn main() -> anyhow::Result<()> {
    // A synthetic smooth function: f(x) = Σ sin(x_i) + ½‖x‖²/D,
    // with analytic gradients to condition on.
    let d = 500;
    let n = 12; // N ≪ D: the paper's low-data regime
    let grad = |x: &[f64]| -> Vec<f64> {
        x.iter().map(|&xi| xi.cos() + xi / d as f64).collect()
    };

    let mut rng = Rng::new(42);
    let mut x = Mat::zeros(d, n);
    let mut g = Mat::zeros(d, n);
    for j in 0..n {
        let xj = rng.uniform_vec(d, -1.5, 1.5);
        g.set_col(j, &grad(&xj));
        x.set_col(j, &xj);
    }

    // Exact inference: O(N²D + N⁶) instead of O(N³D³).
    let t0 = Instant::now();
    let gp = GradientGp::fit(
        Arc::new(SquaredExponential),
        Metric::from_lengthscale((d as f64).sqrt()), // ℓ² = D
        &x,
        &g,
        &FitOptions::default(),
    )?;
    let fit_time = t0.elapsed();
    println!("fitted gradient GP: D = {d}, N = {n}, exact Woodbury solve in {fit_time:?}");
    println!(
        "  (the naive Gram matrix would be {}×{} ≈ {:.1} MB; the factors hold {:.1} KB)",
        n * d,
        n * d,
        ((n * d) * (n * d) * 8) as f64 / 1e6,
        (gp.factors().memory_f64() * 8) as f64 / 1e3,
    );

    // Posterior gradient at a new point vs the truth.
    let xq = rng.uniform_vec(d, -1.0, 1.0);
    let pred = gp.predict_gradient(&xq);
    let truth = grad(&xq);
    let err: f64 = pred
        .iter()
        .zip(&truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        .sqrt()
        / truth.iter().map(|t| t * t).sum::<f64>().sqrt();
    println!("posterior ∇f at a held-out point: relative error {err:.3}");

    // Posterior Hessian (Eq. 12): diagonal + rank-2N structure.
    let h = gp.predict_hessian(&xq);
    println!(
        "posterior Hessian: {}×{}, symmetric (‖H−Hᵀ‖∞ = {:.1e})",
        h.rows(),
        h.cols(),
        (&h - &h.t()).max_abs()
    );

    // Posterior uncertainty on f.
    let var_near = gp.predict_value_var(&xq)?;
    let far = vec![50.0; d];
    let var_far = gp.predict_value_var(&far)?;
    println!("value variance near data: {var_near:.3}; far away: {var_far:.3} (prior = 1)");
    Ok(())
}
