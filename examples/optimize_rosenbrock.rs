//! Nonparametric optimization (Alg. 1) on the 100-D relaxed Rosenbrock
//! (Eq. 17): GP-H and GP-X vs BFGS, all sharing one line search — the
//! Fig. 3 setting as a library-user example.
//!
//! ```bash
//! cargo run --release --example optimize_rosenbrock
//! ```

use std::sync::Arc;

use gdkron::gram::Metric;
use gdkron::kernels::SquaredExponential;
use gdkron::opt::{
    Bfgs, GpHessianOptimizer, GpMinOptimizer, LineSearch, Objective, OptOptions, RelaxedRosenbrock,
};

fn main() {
    let d = 100;
    let obj = RelaxedRosenbrock::new(d);
    let x0 = vec![0.8; d];
    println!(
        "minimizing the relaxed Rosenbrock (Eq. 17), D = {d}, f(x₀) = {:.1}\n",
        obj.value(&x0)
    );
    let shared = OptOptions { gtol: 1e-5, max_iters: 200, line_search: LineSearch::Backtracking };

    let bfgs = Bfgs::new(shared.clone()).minimize(&obj, &x0);
    report("BFGS baseline", &bfgs);

    // App. F.2: RBF kernel, window m = 2, Λ = 9I
    let gph = GpHessianOptimizer {
        kernel: Arc::new(SquaredExponential),
        metric: Metric::Iso(9.0),
        window: 2,
        center: None,
        prior_grad_mean: None,
        online: true,
        opts: shared.clone(),
    }
    .minimize(&obj, &x0);
    report("GP-H (Hessian inference)", &gph);

    // App. F.2: Λ = 0.05I in gradient space
    let gpx = GpMinOptimizer {
        kernel: Arc::new(SquaredExponential),
        metric: Metric::Iso(0.05),
        window: 2,
        center_at_current_gradient: false,
        online: true,
        opts: shared,
    }
    .minimize(&obj, &x0);
    report("GP-X (optimum inference)", &gpx);
}

fn report(name: &str, t: &gdkron::opt::OptTrace) {
    println!(
        "{name:<26}: {:>3} iters | f {:.2e} → {:.2e} | ‖g‖ {:.2e} | {} f-evals, {} g-evals",
        t.iterations(),
        t.f[0],
        t.f.last().unwrap(),
        t.gnorm.last().unwrap(),
        t.f_evals,
        t.g_evals
    );
}
