"""L2 correctness: the Woodbury fit and fused entry points vs the dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)


@pytest.mark.parametrize("d,n", [(5, 3), (8, 4), (12, 6)])
def test_se_fit_matches_dense_solve(d, n):
    il2 = 0.5
    x = rand(10 + d, d, n)
    g = rand(20 + n, d, n)
    z = model.se_fit(x, g, il2)
    z_ref = ref.woodbury_core_solve(x, g, il2)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=5e-3, atol=5e-3)


def test_se_fit_residual_via_matvec():
    """Gram * vec(Z) must reproduce the observations."""
    d, n, il2 = 10, 5, 0.3
    x = rand(1, d, n)
    g = rand(2, d, n)
    z = model.se_fit(x, g, il2)
    back = model.se_gram_matvec(x, z, il2)
    np.testing.assert_allclose(np.asarray(back), np.asarray(g), rtol=2e-3, atol=2e-3)


def test_se_fit_predict_interpolates():
    """Fused fit+predict at the training points returns the observations."""
    d, n, il2 = 8, 4, 0.4
    x = rand(3, d, n)
    g = rand(4, d, n)
    pred = model.se_fit_predict(x, g, x, il2)
    np.testing.assert_allclose(np.asarray(pred), np.asarray(g), rtol=2e-3, atol=2e-3)


def test_se_gram_matvec_matches_ref():
    d, n, il2 = 7, 6, 0.8
    x = rand(5, d, n)
    v = rand(6, d, n)
    got = model.se_gram_matvec(x, v, il2)
    want = ref.gram_matvec(x, v, il2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


def test_lengthscale_is_a_runtime_parameter():
    """One lowered graph must serve different lengthscales (HLO parameter)."""
    d, n = 6, 4
    x = rand(7, d, n)
    v = rand(8, d, n)
    out1 = model.se_gram_matvec(x, v, 0.2)
    out2 = model.se_gram_matvec(x, v, 1.5)
    assert not np.allclose(np.asarray(out1), np.asarray(out2))
    np.testing.assert_allclose(
        np.asarray(out2), np.asarray(ref.gram_matvec(x, v, 1.5)), rtol=2e-4, atol=2e-5
    )


def test_lowering_produces_hlo_text():
    spec = jax.ShapeDtypeStruct((6, 4), jnp.float32)
    sc = jax.ShapeDtypeStruct((), jnp.float32)
    text = model.lower_to_hlo_text(model.se_gram_matvec, spec, spec, sc)
    assert "HloModule" in text
    assert "f32[6,4]" in text
