"""L1 correctness: Pallas kernels vs the pure-jnp oracle vs JAX autodiff.

Two-level oracle chain:
  1. `ref.py` formulas are validated against jax.grad / jax.jacfwd of the
     scalar kernel (the ground truth nobody hand-derived),
  2. the Pallas kernels are validated against `ref.py` over a hypothesis
     sweep of shapes and a dtype check.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gram_matvec import gram_matvec_pallas
from compile.kernels.pairwise import choose_block, pairwise_panels_pallas
from compile.kernels.predict import predict_gradients_pallas

jax.config.update("jax_platform_name", "cpu")


def se_kernel(xa, xb, inv_l2):
    r = jnp.sum((xa - xb) ** 2) * inv_l2
    return jnp.exp(-0.5 * r)


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


# ---------------------------------------------------------------- ref vs autodiff


def test_ref_gram_matvec_matches_autodiff_gram():
    """The structured matvec equals the autodiff cross-derivative Gram matvec."""
    d, n, il2 = 4, 3, 0.7
    key = jax.random.PRNGKey(0)
    x = rand(key, d, n)
    v = rand(jax.random.PRNGKey(1), d, n)
    # dense Gram via autodiff: block (a,b) = d^2 k / dx_a dx_b
    block = jax.jacfwd(jax.grad(se_kernel, argnums=0), argnums=1)
    dense = np.zeros((n * d, n * d))
    for a in range(n):
        for b in range(n):
            blk = block(x[:, a], x[:, b], il2)
            dense[a * d:(a + 1) * d, b * d:(b + 1) * d] = np.asarray(blk)
    want = dense @ np.asarray(v).T.reshape(-1)
    got = np.asarray(ref.gram_matvec(x, v, il2)).T.reshape(-1)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_ref_dense_gram_matches_autodiff():
    d, n, il2 = 3, 3, 0.5
    x = rand(jax.random.PRNGKey(2), d, n)
    block = jax.jacfwd(jax.grad(se_kernel, argnums=0), argnums=1)
    dense = np.asarray(ref.dense_gram(x, il2))
    for a in range(n):
        for b in range(n):
            blk = np.asarray(block(x[:, a], x[:, b], il2))
            np.testing.assert_allclose(
                dense[a * d:(a + 1) * d, b * d:(b + 1) * d], blk, rtol=2e-5, atol=2e-6
            )


def test_ref_predict_interpolates_and_matches_autodiff_cross():
    """Prediction at training inputs reproduces the solved-for observations."""
    d, n, il2 = 4, 3, 0.6
    x = rand(jax.random.PRNGKey(3), d, n)
    g = rand(jax.random.PRNGKey(4), d, n)
    z = ref.woodbury_core_solve(x, g, il2)
    pred = ref.predict_gradients(x, z, x, il2)
    np.testing.assert_allclose(np.asarray(pred), np.asarray(g), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------- pallas vs ref


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=16),
    n=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    il2=st.floats(min_value=0.05, max_value=5.0),
)
def test_pairwise_pallas_matches_ref(d, n, seed, il2):
    x = rand(jax.random.PRNGKey(seed), d, n)
    kp, kpp = pairwise_panels_pallas(x, il2)
    _, kp_ref, kpp_ref = ref.pairwise_panels(x, il2)
    np.testing.assert_allclose(np.asarray(kp), np.asarray(kp_ref), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(kpp), np.asarray(kpp_ref), rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=12),
    n=st.integers(min_value=1, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    il2=st.floats(min_value=0.05, max_value=3.0),
)
def test_gram_matvec_pallas_matches_ref(d, n, seed, il2):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = rand(k1, d, n)
    v = rand(k2, d, n)
    kp, kpp = pairwise_panels_pallas(x, il2)
    got = gram_matvec_pallas(x, v, kp, kpp, il2)
    want = ref.gram_matvec(x, v, il2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=12),
    n=st.integers(min_value=1, max_value=16),
    b=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_predict_pallas_matches_ref(d, n, b, seed):
    il2 = 0.4
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = rand(k1, d, n)
    z = rand(k2, d, n)
    xq = rand(k3, d, b)
    got = predict_gradients_pallas(x, z, xq, il2)
    want = ref.predict_gradients(x, z, xq, il2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


def test_pallas_explicit_blocking_matches_unblocked():
    """Tiled execution (several grid programs) must equal the 1-tile path."""
    d, n, il2 = 8, 12, 0.3
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    x = rand(k1, d, n)
    v = rand(k2, d, n)
    kp, kpp = pairwise_panels_pallas(x, il2, block_n=4)
    kp1, kpp1 = pairwise_panels_pallas(x, il2, block_n=12)
    np.testing.assert_allclose(np.asarray(kp), np.asarray(kp1), rtol=1e-6)
    got = gram_matvec_pallas(x, v, kp, kpp, il2, block_n=3)
    want = gram_matvec_pallas(x, v, kp, kpp, il2, block_n=12)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_float32_inputs_accepted_from_float64():
    """Kernels coerce f64 inputs to f32 (the artifact dtype)."""
    d, n = 4, 4
    x = np.random.RandomState(0).randn(d, n)  # float64
    v = np.random.RandomState(1).randn(d, n)
    kp, kpp = pairwise_panels_pallas(jnp.asarray(x), 0.5)
    out = gram_matvec_pallas(jnp.asarray(x), jnp.asarray(v), kp, kpp, 0.5)
    assert out.dtype == jnp.float32


def test_choose_block_divides():
    for n in [1, 7, 12, 100, 128, 1000]:
        b = choose_block(n)
        assert n % b == 0
        assert b <= 128
