"""L1 Pallas kernel: the structured Gram matvec (Eq. 9 / Alg. 2).

``(grad-K-grad') vec(V)`` for the isotropic SE kernel without materializing
the ND x ND matrix - HBM holds only the O(ND + N^2) factors, exactly the
paper's memory story.

TPU mapping: the grid tiles the *output columns* (observations a). Each
program keeps the full (D, N) X and V panels resident (VMEM budget
2*D*N*4B; 0.8 MB at the Fig. 4 shape D=100, N=1000) and runs three
MXU-shaped contractions per tile:

    term1 = V @ KP[:, tile]                       (D,N)x(N,bn)
    P_row = (X[:, tile]^T @ V) * inv_l2           (bn,D)x(D,N)
    corr  = X @ W^T                               (D,N)x(N,bn)

plus VPU elementwise work for W = KPP_rows * (P_row - diag(P)).

The per-observation diagonal ``pdiag_b = x_b^T Lam v_b`` is passed in
precomputed (one fused multiply-sum at L2) so programs do not redundantly
reduce the full panels.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pairwise import choose_block

__all__ = ["gram_matvec_pallas"]


def _matvec_kernel(x_ref, v_ref, xat_ref, kp_ref, kpp_ref, pdiag_ref, il2_ref, out_ref):
    x = x_ref[...]  # (D, N) full
    v = v_ref[...]  # (D, N) full
    xat = xat_ref[...]  # (D, bn) tile of X (output columns)
    kp_t = kp_ref[...]  # (N, bn) columns-tile of K' (symmetric)
    kpp_t = kpp_ref[...]  # (bn, N) rows-tile of K''
    pdiag = pdiag_ref[...]  # (1, N)
    il2 = il2_ref[0, 0]

    # term1 = V K' (columns tile)
    term1 = jnp.dot(v, kp_t, preferred_element_type=jnp.float32)
    # P rows for the tile: P_{a,b} = x_a^T Lam v_b
    prow = il2 * jnp.dot(xat.T, v, preferred_element_type=jnp.float32)  # (bn, N)
    w = kpp_t * (prow - pdiag)  # (bn, N)
    wsum = jnp.sum(w, axis=1)  # (bn,)
    corr = xat * wsum[None, :] - jnp.dot(x, w.T, preferred_element_type=jnp.float32)
    out_ref[...] = il2 * (term1 + corr)


@functools.partial(jax.jit, static_argnames=("block_n",))
def gram_matvec_pallas(x, v, kp_eff, kpp_eff, inv_l2, block_n=None):
    """Structured matvec via Pallas.

    Args:
      x, v: (D, N) f32; kp_eff, kpp_eff: (N, N) SE panels (from pairwise);
      inv_l2: scalar.

    Returns: (D, N) result of (grad-K-grad') vec(V).
    """
    d, n = x.shape
    bn = block_n or choose_block(n)
    assert n % bn == 0, f"N = {n} must be divisible by block {bn}"
    x = x.astype(jnp.float32)
    v = v.astype(jnp.float32)
    il2 = jnp.asarray(inv_l2, jnp.float32).reshape(1, 1)
    pdiag = (inv_l2 * jnp.sum(x * v, axis=0)).reshape(1, n).astype(jnp.float32)
    grid = (n // bn,)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, n), lambda a: (0, 0)),  # X full
            pl.BlockSpec((d, n), lambda a: (0, 0)),  # V full
            pl.BlockSpec((d, bn), lambda a: (0, a)),  # X tile (output cols)
            pl.BlockSpec((n, bn), lambda a: (0, a)),  # K' cols tile
            pl.BlockSpec((bn, n), lambda a: (a, 0)),  # K'' rows tile
            pl.BlockSpec((1, n), lambda a: (0, 0)),  # pdiag
            pl.BlockSpec((1, 1), lambda a: (0, 0)),  # scalar
        ],
        out_specs=pl.BlockSpec((d, bn), lambda a: (0, a)),
        out_shape=jax.ShapeDtypeStruct((d, n), jnp.float32),
        interpret=True,
    )(x, v, x, kp_eff.astype(jnp.float32), kpp_eff.astype(jnp.float32), pdiag, il2)
