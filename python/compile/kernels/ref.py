"""Pure-jnp reference oracle for the Pallas kernels (L1 correctness signal).

Everything here is the straightforward, unfused implementation of the
paper's formulas for the isotropic squared-exponential kernel
(`k(r) = e^{-r/2}`, `r = ||x_a - x_b||^2 / l^2`):

* ``pairwise_panels`` - the scalar-derivative panels K', K'' (Def. 2),
* ``gram_matvec``     - the O(N^2 D) structured matvec (Eq. 9 / Alg. 2),
* ``predict_gradients`` - batched posterior-mean gradients (App. D.2),
* ``dense_gram`` / ``woodbury_core_solve`` - the materialized ND x ND Gram
  and exact solve (test oracle only; this is exactly the object the paper's
  decomposition avoids).

The pytest suite checks the Pallas kernels against these, and these against
JAX autodiff of the scalar kernel - a two-level oracle chain.
"""

import jax.numpy as jnp

__all__ = [
    "pairwise_panels",
    "gram_matvec",
    "predict_gradients",
    "dense_gram",
    "woodbury_core_solve",
]


def pairwise_panels(x, inv_l2):
    """Pairwise r and the effective SE panels.

    Args:
      x: (D, N) observation locations.
      inv_l2: scalar 1/l^2 (the isotropic metric Lambda = inv_l2 * I).

    Returns:
      (r, kp_eff, kpp_eff): each (N, N); kp_eff = -2 k'(r) = k(r) and
      kpp_eff = -4 k''(r) = -k(r) for the SE kernel - the stationary
      chain-rule factors folded in, matching the rust GramFactors convention.
    """
    q = jnp.sum(x * x, axis=0)  # (N,)
    cross = x.T @ x  # (N, N)
    r = (q[:, None] + q[None, :] - 2.0 * cross) * inv_l2
    r = jnp.maximum(r, 0.0)
    k = jnp.exp(-0.5 * r)
    kp_eff = k
    kpp_eff = -k
    return r, kp_eff, kpp_eff


def gram_matvec(x, v, inv_l2):
    """(grad-K-grad') vec(V) for the isotropic SE kernel, (D, N) in/out."""
    _, kp_eff, kpp_eff = pairwise_panels(x, inv_l2)
    lam_term = inv_l2 * (v @ kp_eff)
    p = inv_l2 * (x.T @ v)  # (N, N): P_ab = x_a^T Lam v_b
    w = kpp_eff * (p - jnp.diag(p)[None, :])  # W_ab = kpp_eff_ab (P_ab - P_bb)
    wsum = jnp.sum(w, axis=1)  # row sums
    corr = inv_l2 * (x * wsum[None, :] - x @ w.T)
    return lam_term + corr


def predict_gradients(x, z, xq, inv_l2):
    """Posterior-mean gradients at query points (App. D.2, SE kernel).

    Args:
      x: (D, N) training locations, z: (D, N) representer weights,
      xq: (D, B) query locations.

    Returns: (D, B) predicted gradients.
    """
    qx = jnp.sum(x * x, axis=0)  # (N,)
    qq = jnp.sum(xq * xq, axis=0)  # (B,)
    cross = x.T @ xq  # (N, B)
    r = (qx[:, None] + qq[None, :] - 2.0 * cross) * inv_l2  # (N, B)
    r = jnp.maximum(r, 0.0)
    k = jnp.exp(-0.5 * r)
    kp = -0.5 * k
    kpp = 0.25 * k
    # m_{b,q} = (xq_q - x_b)^T Lam z_b
    zx = jnp.sum(z * x, axis=0)  # (N,): z_b . x_b
    m = inv_l2 * (z.T @ xq - zx[:, None])  # (N, B)
    # g(xq) = Lam (-2 Z kp - 4 (xq - X)(kpp . m))
    t1 = -2.0 * (z @ kp)  # (D, B)
    wm = kpp * m  # (N, B)
    t2 = -4.0 * (xq * jnp.sum(wm, axis=0)[None, :] - x @ wm)
    return inv_l2 * (t1 + t2)


def dense_gram(x, inv_l2):
    """Materialized ND x ND Gram matrix (oracle only).

    Ordering matches the rust side (Eq. 19): flat index (a, i) -> a*D + i.
    """
    d, n = x.shape
    _, kp_eff, kpp_eff = pairwise_panels(x, inv_l2)
    delta = x[:, :, None] - x[:, None, :]  # (D, N, N): delta[:, a, b]
    lam_delta = inv_l2 * delta
    blocks = kp_eff[None, None, :, :] * (inv_l2 * jnp.eye(d))[:, :, None, None]
    blocks = blocks + kpp_eff[None, None, :, :] * (
        lam_delta[:, None, :, :] * lam_delta[None, :, :, :]
    )
    # (i, j, a, b) -> (a*D+i, b*D+j)
    return jnp.transpose(blocks, (2, 0, 3, 1)).reshape(n * d, n * d)


def woodbury_core_solve(x, g, inv_l2):
    """Exact solve via the dense Gram (oracle): returns Z with shape (D, N)."""
    d, n = x.shape
    gram = dense_gram(x, inv_l2)
    rhs = g.T.reshape(-1)  # (a, i) -> a*D + i ordering
    z = jnp.linalg.solve(gram, rhs)
    return z.reshape(n, d).T
