"""L1 Pallas kernel: pairwise scalar-derivative panels K', K'' (Def. 2).

Computes, for the isotropic SE kernel, the two N x N panels that fully
describe the derivative Gram matrix (Sec. 2.3): ``kp_eff = k(r)`` and
``kpp_eff = -k(r)`` with ``r = ||x_a - x_b||^2 / l^2``.

TPU mapping (DESIGN.md "Hardware adaptation"): the grid tiles the N x N
output into ``(bn, bn)`` blocks; each program loads two (D, bn) panels of X
into VMEM and performs one MXU-shaped ``(bn, D) x (D, bn)`` matmul plus VPU
elementwise work. ``interpret=True`` everywhere on this image - the CPU PJRT
plugin cannot execute Mosaic custom-calls; structure (not wallclock) is what
we optimize at this layer.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pairwise_panels_pallas", "choose_block"]


def choose_block(n, cap=128):
    """Largest divisor of n that is <= cap (TPU-friendly tile width)."""
    best = 1
    for b in range(1, min(n, cap) + 1):
        if n % b == 0:
            best = b
    return best


def _panels_kernel(xa_ref, xb_ref, il2_ref, kp_ref, kpp_ref):
    xa = xa_ref[...]  # (D, bn) rows-tile of X
    xb = xb_ref[...]  # (D, bn) cols-tile of X
    il2 = il2_ref[0, 0]
    qa = jnp.sum(xa * xa, axis=0)
    qb = jnp.sum(xb * xb, axis=0)
    cross = jnp.dot(xa.T, xb, preferred_element_type=jnp.float32)
    r = (qa[:, None] + qb[None, :] - 2.0 * cross) * il2
    r = jnp.maximum(r, 0.0)
    k = jnp.exp(-0.5 * r)
    kp_ref[...] = k
    kpp_ref[...] = -k


@functools.partial(jax.jit, static_argnames=("block_n",))
def pairwise_panels_pallas(x, inv_l2, block_n=None):
    """SE panels via Pallas. x: (D, N) f32; inv_l2: scalar.

    Returns (kp_eff, kpp_eff), each (N, N).
    """
    d, n = x.shape
    bn = block_n or choose_block(n)
    assert n % bn == 0, f"N = {n} must be divisible by block {bn}"
    il2 = jnp.asarray(inv_l2, jnp.float32).reshape(1, 1)
    grid = (n // bn, n // bn)
    out_shape = [
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n, n), jnp.float32),
    ]
    return pl.pallas_call(
        _panels_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, bn), lambda i, j: (0, i)),  # rows-tile
            pl.BlockSpec((d, bn), lambda i, j: (0, j)),  # cols-tile
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),  # scalar
        ],
        out_specs=[
            pl.BlockSpec((bn, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bn), lambda i, j: (i, j)),
        ],
        out_shape=out_shape,
        interpret=True,
    )(x.astype(jnp.float32), x.astype(jnp.float32), il2)
