"""L1 Pallas kernel: batched posterior-mean gradient prediction (App. D.2).

The GPG-HMC hot path: given the fitted representer weights Z, predict
``grad f`` at a batch of query points. Grid tiles the query batch; each
program performs two MXU-shaped contractions against the full (D, N)
training panels (resident in VMEM - at the Fig. 5 shape D=100, N=10 they
are tiny).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pairwise import choose_block

__all__ = ["predict_gradients_pallas"]


def _predict_kernel(x_ref, z_ref, xq_ref, zx_ref, qx_ref, il2_ref, out_ref):
    x = x_ref[...]  # (D, N)
    z = z_ref[...]  # (D, N)
    xq = xq_ref[...]  # (D, bq) query tile
    zx = zx_ref[...]  # (1, N): z_b . x_b
    qx = qx_ref[...]  # (1, N): ||x_b||^2
    il2 = il2_ref[0, 0]

    qq = jnp.sum(xq * xq, axis=0)  # (bq,)
    cross = jnp.dot(x.T, xq, preferred_element_type=jnp.float32)  # (N, bq)
    r = (qx.T + qq[None, :] - 2.0 * cross) * il2
    r = jnp.maximum(r, 0.0)
    k = jnp.exp(-0.5 * r)
    kp = -0.5 * k
    kpp = 0.25 * k
    m = il2 * (jnp.dot(z.T, xq, preferred_element_type=jnp.float32) - zx.T)  # (N, bq)
    t1 = -2.0 * jnp.dot(z, kp, preferred_element_type=jnp.float32)  # (D, bq)
    wm = kpp * m
    t2 = -4.0 * (xq * jnp.sum(wm, axis=0)[None, :]
                 - jnp.dot(x, wm, preferred_element_type=jnp.float32))
    out_ref[...] = il2 * (t1 + t2)


@functools.partial(jax.jit, static_argnames=("block_b",))
def predict_gradients_pallas(x, z, xq, inv_l2, block_b=None):
    """Batched gradient prediction via Pallas.

    Args:
      x, z: (D, N) training locations / representer weights;
      xq: (D, B) query points; inv_l2: scalar.

    Returns: (D, B) posterior-mean gradients.
    """
    d, n = x.shape
    _, b = xq.shape
    bq = block_b or choose_block(b)
    assert b % bq == 0, f"B = {b} must be divisible by block {bq}"
    x = x.astype(jnp.float32)
    z = z.astype(jnp.float32)
    xq = xq.astype(jnp.float32)
    il2 = jnp.asarray(inv_l2, jnp.float32).reshape(1, 1)
    zx = jnp.sum(z * x, axis=0).reshape(1, n)
    qx = jnp.sum(x * x, axis=0).reshape(1, n)
    grid = (b // bq,)
    return pl.pallas_call(
        _predict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, n), lambda q: (0, 0)),
            pl.BlockSpec((d, n), lambda q: (0, 0)),
            pl.BlockSpec((d, bq), lambda q: (0, q)),
            pl.BlockSpec((1, n), lambda q: (0, 0)),
            pl.BlockSpec((1, n), lambda q: (0, 0)),
            pl.BlockSpec((1, 1), lambda q: (0, 0)),
        ],
        out_specs=pl.BlockSpec((d, bq), lambda q: (0, q)),
        out_shape=jax.ShapeDtypeStruct((d, b), jnp.float32),
        interpret=True,
    )(x, z, xq, zx, qx, il2)
