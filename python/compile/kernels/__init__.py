"""L1 Pallas kernels + pure-jnp reference oracle."""

from . import ref  # noqa: F401
from .gram_matvec import gram_matvec_pallas  # noqa: F401
from .pairwise import choose_block, pairwise_panels_pallas  # noqa: F401
from .predict import predict_gradients_pallas  # noqa: F401
