"""L2: JAX compute graphs composed from the L1 Pallas kernels.

These are the functions `aot.py` lowers to HLO-text artifacts for the rust
runtime. Python never runs at request time - each entry point is a pure
function of arrays, jitted once per shape:

* ``se_gram_matvec``  - implicit Gram matvec (Fig. 4 iterative-solver body),
* ``se_fit``          - exact Woodbury solve for the representer weights Z
  (App. C.1, stationary branch, N^2 x N^2 core in-graph),
* ``se_predict``      - batched posterior-mean gradients (GPG-HMC hot path),
* ``se_fit_predict``  - fused fit + predict for one-shot surrogate queries.

All use the isotropic squared-exponential kernel; the scalar ``inv_l2`` is
an HLO *parameter*, so one artifact serves any lengthscale at a fixed shape.
"""

import jax
import jax.numpy as jnp

from .kernels.gram_matvec import gram_matvec_pallas
from .kernels.pairwise import pairwise_panels_pallas
from .kernels.predict import predict_gradients_pallas

__all__ = ["se_gram_matvec", "se_fit", "se_predict", "se_fit_predict"]


def se_gram_matvec(x, v, inv_l2):
    """(grad-K-grad') vec(V): Pallas panels + Pallas matvec."""
    kp_eff, kpp_eff = pairwise_panels_pallas(x, inv_l2)
    return gram_matvec_pallas(x, v, kp_eff, kpp_eff, inv_l2)


FIT_CG_ITERS = 256


def se_fit(x, g, inv_l2):
    """In-graph solve of (grad-K-grad') vec(Z) = vec(G): returns Z (D, N).

    Implemented as ``FIT_CG_ITERS`` iterations of Jacobi-preconditioned CG on
    the structured matvec (Sec. 2.3 "General Improvements"). Deliberately
    *not* ``jnp.linalg``: LAPACK lowers to typed-FFI custom-calls that the
    deployment XLA (xla_extension 0.5.1) rejects, while this loop is pure
    HLO — and it is the same iterative engine the paper proposes for the
    `N > D` regime, here specialized to the artifact's fixed shape. The
    iteration count is a static bound; convergence at the shipped shapes is
    certified by `python/tests/test_model.py` + the rust cross-check.
    """
    import jax.lax as lax

    x = x.astype(jnp.float32)
    g = g.astype(jnp.float32)
    kp_eff, kpp_eff = pairwise_panels_pallas(x, inv_l2)

    def matvec(v):
        lam_term = inv_l2 * (v @ kp_eff)
        p = inv_l2 * (x.T @ v)
        w = kpp_eff * (p - jnp.diag(p)[None, :])
        wsum = jnp.sum(w, axis=1)
        corr = inv_l2 * (x * wsum[None, :] - x @ w.T)
        return lam_term + corr

    # Jacobi preconditioner: Gram diagonal = kp_eff_aa * inv_l2 (the
    # stationary correction vanishes on the diagonal).
    diag = jnp.diag(kp_eff) * inv_l2  # (N,)
    precond = lambda r: r / diag[None, :]

    z0 = jnp.zeros_like(g)
    r0 = g
    p0 = precond(r0)
    rz0 = jnp.sum(r0 * p0)

    def body(_, state):
        z, r, p, rz = state
        ap = matvec(p)
        pap = jnp.sum(p * ap)
        alpha = rz / jnp.maximum(pap, 1e-30)
        z = z + alpha * p
        r = r - alpha * ap
        s = precond(r)
        rz_new = jnp.sum(r * s)
        beta = rz_new / jnp.maximum(rz, 1e-30)
        p = s + beta * p
        return z, r, p, rz_new

    z, _, _, _ = lax.fori_loop(0, FIT_CG_ITERS, body, (z0, r0, p0, rz0))
    return z


def se_predict(x, z, xq, inv_l2):
    """Batched posterior-mean gradients at query points (Pallas)."""
    return predict_gradients_pallas(x, z, xq, inv_l2)


def se_fit_predict(x, g, xq, inv_l2):
    """Fused fit + batched predict (one-shot surrogate queries)."""
    z = se_fit(x, g, inv_l2)
    return se_predict(x, z, xq, inv_l2)


def lower_to_hlo_text(fn, *args):
    """Lower a jitted function to HLO text (the rust-loadable format).

    HLO *text*, not a serialized proto: jax >= 0.5 emits 64-bit instruction
    ids that xla_extension 0.5.1 rejects; the text parser reassigns ids.
    """
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
